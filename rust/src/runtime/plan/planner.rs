//! Cost-driven scheduler: picks each node's tiling, fan-out and fusion
//! from the analytic roofline model instead of hard-coded constants
//! (DESIGN.md §7).
//!
//! The model is the host-CPU roofline (`perf::CPU_HOST`) divided evenly
//! across the pool's workers, plus the measured dispatch envelope of
//! `util::threadpool`. For a node with `J` parallel jobs out of `W`
//! workers:
//!
//! ```text
//! t(J) = ⌈J/W⌉ · max( (F/J)/f₁ , (S/J)/b₁ ) + shared/B + J·d + j₀
//! ```
//!
//! where `f₁ = F_chip/W`, `b₁ = B_chip/W` are per-worker peaks, `S`
//! streams across jobs, `shared` (a weight matrix) is streamed once at
//! chip bandwidth and then cache-resident, `d` is the per-job dispatch
//! cost and `j₀` the scoped-join cost. `J = 1` is the serial candidate
//! (no dispatch) — which is how the old `PAR_MIN_FLOPS` threshold falls
//! out of the model instead of being pinned by hand: tiny contractions
//! price out to serial, large ones to `W`-way row blocks.
//!
//! Fusion decisions go through the same loop: a dataflow grouping pass
//! (`choose_regions`) merges runs of row-pointwise producer→consumer
//! nodes into fusion regions whenever the merged roofline price — the
//! bytes the region never re-materialises through DRAM minus its
//! per-row loop re-entry overhead (`perf::roofline::FUSE_LOOP_S`) —
//! beats the members' standalone prices (DESIGN.md §12).
//! Bandwidth-bound decode fuses aggressively; compute-bound prefill
//! only where the epilogue is free. Membership is priced ISA-blind
//! (scalar tier), so the kernel tier can never shift what fuses, and
//! the whole pass is gated by `FuseMode` (`M2_FUSE`) so the unfused
//! plan stays reachable as the bitwise parity oracle.

use std::time::Instant;

use crate::perf::roofline::{isa_scales, CPU_HOST, FUSE_LOOP_S};
use crate::runtime::backend::analytic_cost;
use crate::runtime::manifest::{RegionInfo, ScheduleInfo, WeightsDtype};
use crate::runtime::ConfigInfo;
use crate::tensor::kernels::Isa;

use super::ir::{self, Op, WeightRepr, Work};
use super::{ArenaPool, Entry, ExecRegion, FuseMode, Plan, PlanKey};

/// Per-job dispatch cost of `util::threadpool` (mpsc enqueue + worker
/// wake-up), measured envelope on the container class CI runs on — the
/// pool-level analogue of the rooflines' launch overheads.
pub const DISPATCH_S: f64 = 2.0e-6;
/// One-time cost of a scoped parallel region (join + channel teardown).
pub const JOIN_S: f64 = 4.0e-6;
/// L1-resident budget for one f32 weight panel of the tile pack (half a
/// typical 32 KiB L1D, leaving room for the A row and the C tile) —
/// the cache-hierarchy constant the layout pass prices against, the way
/// `DISPATCH_S` stands in for the pool envelope.
pub const L1_PANEL_BYTES: usize = 16 * 1024;
/// Minimum output rows before panel re-residency amortises the tiled
/// loop structure: below this a weight matrix is streamed so few times
/// that repacking buys nothing (the decode path at every serving width).
pub const TILE_MIN_ROWS: usize = 32;
/// Fan-out candidates, in waves of the worker count: `J ∈ {W, 2W, 4W,
/// 8W}` plus the serial form. More waves buy load balance on ragged
/// job counts at the price of dispatch.
const WAVE_CANDIDATES: [usize; 4] = [1, 2, 4, 8];
/// Scalar-tier cost of one transcendental evaluation (libm `expf` call
/// through the softplus/silu/decay paths), measured envelope on the CI
/// container class — the third axis of the ISA pricing model
/// (DESIGN.md §11) next to the roofline's flops and bytes.
pub const TRANSC_S: f64 = 2.0e-8;
/// A vector tier must beat the scalar price by this relative margin
/// before the planner retiers a node: SIMD trades bitwise parity for
/// speed, so a wash prices out to the exact scalar kernels.
pub const ISA_MARGIN: f64 = 0.02;

/// Execution schedule of one node, chosen by the cost loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sched {
    /// run on the calling thread in the canonical scalar order
    Serial,
    /// contraction row blocks: `rows` output rows per block, `blocks`
    /// pool dispatches (bitwise-invariant — each C row is produced by
    /// exactly one block in the serial scalar order)
    RowBlock { rows: usize, blocks: usize },
    /// chunk-stage tiling: `group` (seq, head, chunk) cells per
    /// dispatch, `dispatches` dispatches (bitwise-invariant — each cell
    /// runs the serial scalar schedule)
    JobGroup { group: usize, dispatches: usize },
}

fn chip_bw() -> f64 {
    let (_, bc) = CPU_HOST.worker_peaks(1);
    bc
}

/// Serial wall-time of `work` when one worker (of `threads` sharing the
/// chip) runs it.
fn serial_time(w: &Work, threads: usize) -> f64 {
    let (f1, b1) = CPU_HOST.worker_peaks(threads);
    (w.flops / f1).max(w.stream_bytes / b1) + w.shared_bytes / chip_bw()
}

/// Parallel wall-time with `jobs` dispatches over `threads` workers
/// (see the module docs for the model).
fn par_time(w: &Work, jobs: usize, threads: usize) -> f64 {
    let (f1, b1) = CPU_HOST.worker_peaks(threads);
    let waves = jobs.div_ceil(threads) as f64;
    let per_wave = ((w.flops / jobs as f64) / f1)
        .max((w.stream_bytes / jobs as f64) / b1);
    waves * per_wave + w.shared_bytes / chip_bw()
        + jobs as f64 * DISPATCH_S + JOIN_S
}

/// Price `work` under an already-chosen schedule on a kernel-tier ISA
/// (DESIGN.md §11). Unlike [`serial_time`]/[`par_time`] — which pick
/// the fan-out and are deliberately left ISA-blind so schedules never
/// shift under retiering — this overlaps compute against the full
/// memory stream: the compute term (flops at the ISA's scaled peak,
/// plus transcendentals at [`TRANSC_S`] over the ISA's polynomial-exp
/// scale) races the bandwidth term (streamed + shared bytes, never
/// ISA-scaled). Wider lanes therefore only pay off where compute or
/// transcendentals bind; bandwidth-bound nodes price identically on
/// every tier and stay scalar under [`ISA_MARGIN`].
fn isa_time(w: &Work, sched: Sched, threads: usize, isa: Isa) -> f64 {
    let (cs, _, ts) = isa_scales(isa);
    let (f1, b1) = CPU_HOST.worker_peaks(threads);
    let jobs = match sched {
        Sched::Serial => 1,
        Sched::RowBlock { blocks, .. } => blocks,
        Sched::JobGroup { dispatches, .. } => dispatches,
    };
    let waves = jobs.div_ceil(threads) as f64;
    let j = jobs as f64;
    let compute = waves
        * (w.flops / j / (f1 * cs) + w.transc / j * TRANSC_S / ts);
    let memory = waves * (w.stream_bytes / j / b1)
        + w.shared_bytes / chip_bw();
    let overhead =
        if jobs > 1 { j * DISPATCH_S + JOIN_S } else { 0.0 };
    compute.max(memory) + overhead
}

/// Choose a schedule for one node: serial vs every wave candidate,
/// lowest predicted time wins (strict `<`, so ties stay at the coarser
/// grain). Returns the schedule and its predicted seconds.
fn choose(w: &Work, threads: usize, row_block: bool) -> (Sched, f64) {
    let mut best = (Sched::Serial, serial_time(w, threads));
    if w.jobs <= 1 || threads <= 1 {
        return best;
    }
    for &waves in &WAVE_CANDIDATES {
        let target = threads * waves;
        let grain = w.jobs.div_ceil(target).max(1);
        let jobs = w.jobs.div_ceil(grain);
        if jobs <= 1 {
            continue;
        }
        let t = par_time(w, jobs, threads);
        if t < best.1 {
            let sched = if row_block {
                Sched::RowBlock { rows: grain, blocks: jobs }
            } else {
                Sched::JobGroup { group: grain, dispatches: jobs }
            };
            best = (sched, t);
        }
    }
    best
}

/// At most one contraction per region: the row-interleaved region loop
/// runs on the calling thread, so a second matmul would always pile
/// serialised compute onto a region that the first one's saved bytes
/// can never repay (and one accumulating contraction already gives the
/// residual epilogue its free ride).
const REGION_MM_CAP: usize = 1;

/// Buffers node `j` reads: its declared inputs plus its own output when
/// the op accumulates into it ([`Op::reads_out`]).
fn read_set(node: &ir::Node) -> Vec<usize> {
    let mut r: Vec<usize> = node.ins.iter().map(|b| b.0).collect();
    if node.op.reads_out() {
        for b in &node.outs {
            if !r.contains(&b.0) {
                r.push(b.0);
            }
        }
    }
    r
}

/// Latest writer of buffer `b` strictly before node `j`, if any.
fn writer_before(graph: &ir::Graph, b: usize, j: usize) -> Option<usize> {
    (0..j).rev().find(|&i| graph.nodes[i].outs.iter().any(|o| o.0 == b))
}

/// The readers of the value node `j` writes into buffer `b`: every
/// later node that reads `b` up to and including the next writer (which
/// reads the old value too when it accumulates or lists `b` as an
/// input); the value is dead past that writer.
fn readers_of_write(graph: &ir::Graph, b: usize, j: usize) -> Vec<usize> {
    let mut readers = Vec::new();
    for (i, node) in graph.nodes.iter().enumerate().skip(j + 1) {
        let reads = node.ins.iter().any(|x| x.0 == b)
            || (node.op.reads_out()
                && node.outs.iter().any(|x| x.0 == b));
        if reads {
            readers.push(i);
        }
        if node.outs.iter().any(|x| x.0 == b) {
            break;
        }
    }
    readers
}

/// Merge nodes `lo..=hi` into one [`Work`], shaving off the streamed
/// bytes the fused row loop never re-materialises through DRAM
/// (DESIGN.md §12):
///
///   * read edges whose latest prior writer sits inside the candidate —
///     the value is still cache-hot from the same row iteration,
///   * writes whose every reader sits inside the candidate — the store
///     never needs to reach DRAM at all (slab elision collects these).
///
/// Shared (weight) bytes are never saved: fusion does not change what a
/// contraction streams from its weight matrix. Returns the merged work
/// and the bytes actually shaved (clamped so a region can never price
/// negative traffic).
fn merged_work(graph: &ir::Graph, lo: usize, hi: usize) -> (Work, f64) {
    let mut w = Work::default();
    let mut stream = 0.0;
    let mut saved = 0.0;
    for j in lo..=hi {
        let node = &graph.nodes[j];
        w.flops += node.work.flops;
        w.transc += node.work.transc;
        w.shared_bytes += node.work.shared_bytes;
        stream += node.work.stream_bytes;
        for b in read_set(node) {
            if let Some(wr) = writer_before(graph, b, j) {
                if wr >= lo {
                    saved += graph.bufs[b].len() as f64 * 4.0;
                }
            }
        }
        for out in &node.outs {
            let readers = readers_of_write(graph, out.0, j);
            if !readers.is_empty()
                && readers.iter().all(|&r| r >= lo && r <= hi) {
                saved += graph.bufs[out.0].len() as f64 * 4.0;
            }
        }
    }
    let saved = saved.min(stream);
    w.stream_bytes = stream - saved;
    w.jobs = 1;
    (w, saved)
}

/// One chosen fusion region before it is written onto the plan.
struct RegionPick {
    lo: usize,
    hi: usize,
    /// merged work with the saved bytes already subtracted
    work: Work,
    /// streamed bytes the merge shaves off per invocation
    saved: f64,
}

/// The greedy fusion-region pass: scan the node list forward, start a
/// candidate at each fusable node, and extend it while the next node is
/// fusable, the contraction cap holds, and the merged region prices
/// strictly under the current region plus the next node's standalone
/// (chosen-schedule) cost. The standalone baseline is what makes the
/// pass cost-chosen rather than greedy-maximal: serialising a
/// fanned-out matmul into a region must pay for itself against its
/// parallel price, so compute-bound prefill keeps its row-blocked
/// contractions unfused while bandwidth-bound decode chains fuse
/// nearly end-to-end. Priced entirely on the scalar tier so the ISA
/// request can never shift membership.
fn choose_regions(graph: &ir::Graph, threads: usize, rows: usize,
                  standalone: &[f64]) -> Vec<RegionPick> {
    let n = graph.nodes.len();
    let is_mm = |i: usize| {
        matches!(graph.nodes[i].op, Op::MatMul { .. }) as usize
    };
    let region_t = |w: &Work, members: usize| {
        isa_time(w, Sched::Serial, threads, Isa::Scalar)
            + rows as f64 * (members - 1) as f64 * FUSE_LOOP_S
    };
    let mut picks = Vec::new();
    let mut i = 0;
    while i < n {
        if !graph.nodes[i].op.fusable() {
            i += 1;
            continue;
        }
        let mut hi = i;
        let mut mms = is_mm(i);
        let mut cur_t = standalone[i];
        let mut cur: Option<(Work, f64)> = None;
        loop {
            let next = hi + 1;
            if next >= n || !graph.nodes[next].op.fusable()
                || mms + is_mm(next) > REGION_MM_CAP {
                break;
            }
            let (w, saved) = merged_work(graph, i, next);
            let cand_t = region_t(&w, next - i + 1);
            if cand_t < cur_t + standalone[next] {
                hi = next;
                mms += is_mm(next);
                cur_t = cand_t;
                cur = Some((w, saved));
            } else {
                break;
            }
        }
        if let Some((work, saved)) = cur {
            picks.push(RegionPick { lo: i, hi, work, saved });
            i = hi + 1;
        } else {
            i += 1;
        }
    }
    picks
}

/// Per-buffer slab elision (DESIGN.md §12): a buffer whose every write
/// happens inside a fusion region and is fully consumed inside that
/// same region never holds more than one live row at a time in the
/// row-interleaved loop, so the memory plan backs it with a single
/// scratch row instead of `rows` rows. The graph's final output is
/// never elided — it leaves the plan.
fn elide_bufs(graph: &ir::Graph, picks: &[RegionPick]) -> Vec<bool> {
    let region_of = |i: usize| {
        picks.iter().position(|p| i >= p.lo && i <= p.hi)
    };
    let last_out = graph.nodes.last().map(|n| n.outs[0].0);
    let mut elided = vec![false; graph.bufs.len()];
    for b in 0..graph.bufs.len() {
        if Some(b) == last_out {
            continue;
        }
        let writers: Vec<usize> = (0..graph.nodes.len())
            .filter(|&j| graph.nodes[j].outs.iter().any(|o| o.0 == b))
            .collect();
        if writers.is_empty() {
            continue;
        }
        elided[b] = writers.iter().all(|&j| match region_of(j) {
            Some(r) => readers_of_write(graph, b, j).iter()
                .all(|&rd| region_of(rd) == Some(r)),
            None => false,
        });
    }
    elided
}

/// Recording rank for a region's ISA tag (scalar < neon < avx2): the
/// region records the highest member tier, purely descriptive — each
/// member row body still dispatches through its own node ISA.
fn isa_rank(isa: Isa) -> u8 {
    match isa {
        Isa::Scalar => 0,
        Isa::Neon => 1,
        Isa::Avx2 => 2,
    }
}

/// Panel width of the f32 tile pack for a `(k, n)` weight: the widest
/// power of two whose `k × tile` f32 panel fits [`L1_PANEL_BYTES`]
/// (floor 8, capped at `n`). Pure function of the weight shape, so one
/// prepack per matrix serves every plan that tiles it.
pub fn tile_for(k: usize, n: usize) -> usize {
    let mut t = 8usize;
    while t * 2 <= n && k * (t * 2) * 4 <= L1_PANEL_BYTES {
        t *= 2;
    }
    t.min(n.max(1))
}

/// The precision-and-layout half of a MatMul node's schedule
/// (DESIGN.md §8): pick the weight representation, returning it with
/// the node's `Work` adjusted to what that representation streams.
///
///   * decode entrypoints in a reduced precision mode (bf16, int8, q4)
///     price that representation's weight stream against the f32 one
///     over [`Roofline::worker_peaks`]'s bandwidth terms — the shared
///     weight bytes scale by `WeightRepr::bytes_per_weight() / 4`
///     (code stream plus amortised group scales for the quantised
///     forms), so with any shared weight bytes at all the reduced form
///     is strictly cheaper and the bandwidth-bound decode path always
///     takes it (a unit test pins the strictness, since the BENCH
///     acceptance gate relies on it),
///   * prefill matmuls keep f32 regardless of the knob (exactness is
///     free where compute, not weight bandwidth, binds the roofline —
///     see DESIGN.md §8/§13 for the priced comparison; this is also
///     what keeps prefill bitwise under every `--weights` mode) but
///     repack into column panels once the weight exceeds the L1 budget
///     and the row count re-streams it often enough to amortise panel
///     residency. Bitwise identical to dense by construction.
fn choose_repr(entry: Entry, weights: WeightsDtype, quant_group: usize,
               threads: usize, mkn: (usize, usize, usize), work: &Work)
    -> (WeightRepr, Work) {
    let (m, k, n) = mkn;
    let reduced = match weights {
        WeightsDtype::F32 => None,
        WeightsDtype::Bf16 => Some(WeightRepr::Bf16),
        WeightsDtype::Int8 => {
            Some(WeightRepr::Int8Group { group: quant_group })
        }
        WeightsDtype::Q4 => {
            Some(WeightRepr::Q4Group { group: quant_group })
        }
    };
    if entry == Entry::Decode {
        if let Some(r) = reduced {
            let mut w2 = work.clone();
            w2.shared_bytes *= r.bytes_per_weight() / 4.0;
            let f32_t = serial_time(work, threads);
            let red_t = serial_time(&w2, threads);
            if red_t < f32_t {
                return (r, w2);
            }
            // unreachable while weights have nonzero bytes; kept so the
            // decision stays priced rather than hard-wired
            return (WeightRepr::F32Dense, work.clone());
        }
    }
    if m >= TILE_MIN_ROWS && k * n * 4 > L1_PANEL_BYTES {
        return (WeightRepr::F32Tiled { tile: tile_for(k, n) },
                work.clone());
    }
    (WeightRepr::F32Dense, work.clone())
}

/// Build and schedule the plan for one `(entrypoint, batch, t)` shape
/// bucket. Pure function of `(cfg, key, threads, weights, isa, fuse)` —
/// the same inputs always produce the same schedule (the golden
/// `plan_dump` test pins that).
///
/// `isa` is the backend's *requested* kernel tier (already resolved
/// against host capability): fan-out and fusion are chosen ISA-blind,
/// then every classed node is priced scalar-vs-requested through
/// [`isa_time`] and retiers only on a ≥ [`ISA_MARGIN`] win. With
/// `Isa::Scalar` the plan is identical to the pre-kernel-tier output.
/// `fuse` gates the fusion-region pass; under [`FuseMode::Off`] every
/// node executes standalone and the slab stays fully dense (the
/// bitwise parity oracle of `tests/fusion_parity.rs`).
pub fn build_plan(cfg: &ConfigInfo, key: PlanKey, threads: usize,
                  weights: WeightsDtype, quant_group: usize, isa: Isa,
                  fuse: FuseMode)
    -> Plan {
    let t0 = Instant::now();
    let mut graph = match key.entry {
        Entry::Prefill => ir::lower_prefill(cfg, key.batch, key.t),
        Entry::Decode => ir::lower_decode(cfg, key.batch),
    };
    let mut node_secs: Vec<f64> = Vec::with_capacity(graph.nodes.len());
    let mut scalar_secs: Vec<f64> = Vec::with_capacity(graph.nodes.len());
    let mut row_block = 0usize;
    let mut chunk_tile = 0usize;
    let mut layout = String::new();
    let mut repr_saved_bytes = 0.0f64;
    for node in &mut graph.nodes {
        let is_mm = matches!(node.op, Op::MatMul { .. });
        // precision/layout first — the chosen representation changes
        // the bytes the fan-out loop below prices
        if let (Op::MatMul { repr, .. }, Some(mkn)) =
            (&mut node.op, node.mkn) {
            let (r, w) = choose_repr(key.entry, weights, quant_group,
                                     threads, mkn, &node.work);
            let bpw = r.bytes_per_weight();
            if bpw < 4.0 {
                // the invocation-level cost drops by the f32→reduced
                // weight-byte saving per contraction (k·n·2 for bf16,
                // k·n·(4 − 1 − 4/g) for int8, … — scales included)
                repr_saved_bytes += (mkn.1 * mkn.2) as f64 * (4.0 - bpw);
            }
            if layout.is_empty() && r != WeightRepr::F32Dense {
                layout = match r {
                    WeightRepr::F32Tiled { tile } => format!("tile{tile}"),
                    WeightRepr::Bf16 => "bf16-rows".into(),
                    WeightRepr::Int8Group { group } => {
                        format!("int8-g{group}-rows")
                    }
                    WeightRepr::Q4Group { group } => {
                        format!("q4-g{group}-rows")
                    }
                    WeightRepr::F32Dense => unreachable!(),
                };
            }
            *repr = r;
            node.work = w;
        }
        let (sched, _) = choose(&node.work, threads, is_mm);
        node.sched = sched;
        // kernel-tier assignment: only classed nodes may leave the
        // scalar tier, and only when the requested ISA prices a clear
        // win under the chosen schedule (the margin keeps bitwise
        // parity wherever SIMD would merely tie)
        let t_scalar = isa_time(&node.work, sched, threads, Isa::Scalar);
        let (node_isa, isa_secs) = match (node.op.kernel_class(), isa) {
            (Some(_), req) if req != Isa::Scalar => {
                let t_req = isa_time(&node.work, sched, threads, req);
                if t_req < t_scalar * (1.0 - ISA_MARGIN) {
                    (req, t_req)
                } else {
                    (Isa::Scalar, t_scalar)
                }
            }
            _ => (Isa::Scalar, t_scalar),
        };
        node.isa = node_isa;
        node_secs.push(isa_secs);
        scalar_secs.push(t_scalar);
        if row_block == 0 {
            if let Sched::RowBlock { rows, .. } = node.sched {
                row_block = rows;
            }
        }
        if chunk_tile == 0 {
            if let Sched::JobGroup { group, .. } = node.sched {
                chunk_tile = group;
            }
        }
    }
    // the fusion-region pass (DESIGN.md §12): ISA-blind, standalone
    // prices as the baseline, gated by the M2_FUSE knob
    let rows = key.batch * key.t;
    let picks = match fuse {
        FuseMode::On => choose_regions(&graph, threads, rows,
                                       &scalar_secs),
        FuseMode::Off => Vec::new(),
    };
    let regions: Vec<ExecRegion> = picks.iter().map(|p| {
        let r_isa = (p.lo..=p.hi).map(|i| graph.nodes[i].isa)
            .max_by_key(|&i| isa_rank(i)).unwrap_or(Isa::Scalar);
        ExecRegion { lo: p.lo, hi: p.hi, isa: r_isa }
    }).collect();
    let bytes_elided: f64 = picks.iter().map(|p| p.saved).sum();
    let elided = elide_bufs(&graph, &picks);
    // predicted wall-clock: standalone nodes at their chosen tier,
    // each region as one serial row-interleaved loop at its tier
    let mut est = 0.0;
    for (i, secs) in node_secs.iter().enumerate() {
        match picks.iter().position(|p| i >= p.lo && i <= p.hi) {
            Some(k) => {
                if i == picks[k].lo {
                    est += isa_time(&picks[k].work, Sched::Serial,
                                    threads, regions[k].isa)
                        + rows as f64
                            * (picks[k].hi - picks[k].lo) as f64
                            * FUSE_LOOP_S;
                }
            }
            None => est += secs,
        }
    }
    // the whole-invocation analytic cost, computed ONCE here and stored
    // on the plan so benches/metrics never recompute it per call;
    // reduced-precision weight streams (bf16/int8/q4) shave their saved
    // bytes off the f32 model
    let mut cost = match key.entry {
        Entry::Prefill => analytic_cost(cfg, "prefill", Some(key.t),
                                        key.batch),
        Entry::Decode => analytic_cost(cfg, "decode_step", None,
                                       key.batch),
    };
    cost.bytes_accessed -= repr_saved_bytes;
    // the byte-model total the schedule was chosen against — what
    // BENCH_*.json reports as bytes_streamed_per_token (÷ batch);
    // fusion shaves its elided bytes off here (never off CostInfo,
    // which stays the entrypoint-level analytic model)
    let stream_bytes: f64 = graph.nodes.iter()
        .map(|n| n.work.shared_bytes + n.work.stream_bytes)
        .sum::<f64>() - bytes_elided;
    let schedule = ScheduleInfo {
        chunk_tile,
        row_block,
        fanout: threads,
        regions: picks.iter().zip(&regions).map(|(p, r)| RegionInfo {
            members: (p.lo..=p.hi)
                .map(|i| graph.nodes[i].op.label())
                .collect(),
            isa: r.isa.label().to_string(),
        }).collect(),
        weights_dtype: weights.as_str().to_string(),
        weight_layout: if layout.is_empty() {
            "dense".to_string()
        } else {
            layout
        },
        isa: isa.label().to_string(),
    };
    // the memory plan: every BufSpec compiles to an offset in one
    // per-plan slab, sized and seeded here so steady-state execution
    // allocates nothing (exec::Arena checks slabs in and out).
    // Non-elided buffers pack densely in declaration order; elided
    // intermediates get one scratch row each at the slab tail.
    let mut buf_offsets = vec![(0usize, 0usize); graph.bufs.len()];
    let mut slab_len = 0usize;
    for (i, b) in graph.bufs.iter().enumerate() {
        if !elided[i] {
            buf_offsets[i] = (slab_len, b.len());
            slab_len += b.len();
        }
    }
    for (i, b) in graph.bufs.iter().enumerate() {
        if elided[i] {
            buf_offsets[i] = (slab_len, b.width);
            slab_len += b.width;
        }
    }
    Plan {
        key,
        cfg_name: cfg.name.clone(),
        chunk_size: cfg.chunk_size,
        threads,
        weights,
        graph,
        cost,
        schedule,
        est_seconds: est,
        stream_bytes,
        planning_ms: t0.elapsed().as_secs_f64() * 1e3,
        regions,
        elided,
        bytes_elided,
        buf_offsets,
        slab_len,
        arenas: ArenaPool::with_first(slab_len),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sim_config;

    fn plan(cfg_name: &str, entry: Entry, batch: usize, t: usize,
            threads: usize) -> Plan {
        plan_w(cfg_name, entry, batch, t, threads, WeightsDtype::F32)
    }

    fn plan_w(cfg_name: &str, entry: Entry, batch: usize, t: usize,
              threads: usize, weights: WeightsDtype) -> Plan {
        let cfg = sim_config(cfg_name).unwrap();
        build_plan(&cfg, PlanKey { entry, batch, t }, threads, weights,
                   64, Isa::Scalar, FuseMode::On)
    }

    fn plan_isa(cfg_name: &str, entry: Entry, batch: usize, t: usize,
                threads: usize, isa: Isa) -> Plan {
        let cfg = sim_config(cfg_name).unwrap();
        build_plan(&cfg, PlanKey { entry, batch, t }, threads,
                   WeightsDtype::F32, 64, isa, FuseMode::On)
    }

    fn plan_fuse(cfg_name: &str, entry: Entry, batch: usize, t: usize,
                 threads: usize, fuse: FuseMode) -> Plan {
        let cfg = sim_config(cfg_name).unwrap();
        build_plan(&cfg, PlanKey { entry, batch, t }, threads,
                   WeightsDtype::F32, 64, Isa::Scalar, fuse)
    }

    #[test]
    fn tiny_contractions_price_out_to_serial() {
        // batch-1 decode: every contraction has one output row — the
        // dispatch term dominates any fan-out, so the plan stays serial
        let p = plan("sim-130m", Entry::Decode, 1, 1, 8);
        for node in &p.graph.nodes {
            assert_eq!(node.sched, Sched::Serial, "{}", node.op.label());
        }
        assert_eq!(p.schedule.row_block, 0);
    }

    #[test]
    fn large_contractions_fan_out() {
        // a 512-token prefill is compute-bound: projections and both
        // chunk stages must fan out across the 8 workers
        let p = plan("sim-130m", Entry::Prefill, 1, 512, 8);
        let mut mm_par = 0;
        let mut jobs_par = 0;
        for node in &p.graph.nodes {
            match node.sched {
                Sched::RowBlock { rows, blocks } => {
                    assert!(rows * blocks >= 512, "{}", node.op.label());
                    mm_par += 1;
                }
                Sched::JobGroup { group, dispatches } => {
                    assert!(group * dispatches >= node.work.jobs);
                    jobs_par += 1;
                }
                Sched::Serial => {}
            }
        }
        assert!(mm_par >= 3, "projections stayed serial");
        assert!(jobs_par >= 2, "chunk stages stayed serial");
        assert!(p.schedule.row_block > 0);
        assert!(p.schedule.chunk_tile > 0);
    }

    #[test]
    fn serial_backend_gets_serial_plans() {
        let p = plan("sim-130m", Entry::Prefill, 1, 512, 1);
        assert!(p.graph.nodes.iter()
            .all(|n| n.sched == Sched::Serial));
    }

    #[test]
    fn fusion_is_chosen_by_cost_on_every_config() {
        // the region pass must find savings everywhere on the ladder,
        // and every region it picks must be legal: disjoint ascending
        // index ranges, row-pointwise members only, at most one
        // contraction, at least two members (a singleton "region" is
        // just a standalone node)
        for name in ["tiny", "sim-130m", "sim-370m", "sim-780m",
                     "sim-1.3b", "sim-2.7b"] {
            for (entry, t) in [(Entry::Prefill, 64), (Entry::Decode, 1)] {
                let p = plan(name, entry, 2, t, 8);
                assert!(!p.regions.is_empty(), "{name} {entry:?}");
                let mut prev_hi = None;
                for r in &p.regions {
                    assert!(r.lo < r.hi, "{name}: singleton region");
                    assert!(r.hi < p.graph.nodes.len());
                    if let Some(ph) = prev_hi {
                        assert!(r.lo > ph, "{name}: overlapping regions");
                    }
                    prev_hi = Some(r.hi);
                    let mms = (r.lo..=r.hi).filter(|&i| matches!(
                        p.graph.nodes[i].op, Op::MatMul { .. })).count();
                    assert!(mms <= REGION_MM_CAP, "{name}");
                    for i in r.lo..=r.hi {
                        assert!(p.graph.nodes[i].op.fusable(),
                                "{name}: {}",
                                p.graph.nodes[i].op.label());
                    }
                }
                // ...and the manifest record mirrors the chosen list
                assert_eq!(p.schedule.regions.len(), p.regions.len());
                for (ri, r) in p.schedule.regions.iter()
                    .zip(&p.regions) {
                    assert_eq!(ri.members.len(), r.hi - r.lo + 1);
                }
            }
        }
    }

    #[test]
    fn decode_fuses_more_than_prefill() {
        // the ISSUE-level shape of the pass: bandwidth-bound decode
        // chains fuse nearly end-to-end, compute-bound prefill only
        // where the epilogue is free — so decode B=1 covers strictly
        // more nodes with regions than a long prefill, and clears the
        // acceptance floor of 3 regions
        let cov = |p: &Plan| p.regions.iter()
            .map(|r| r.hi - r.lo + 1).sum::<usize>();
        let d = plan("sim-130m", Entry::Decode, 1, 1, 8);
        let p = plan("sim-130m", Entry::Prefill, 1, 2048, 8);
        assert!(d.regions.len() >= 3, "decode regions: {:?}", d.regions);
        assert!(cov(&d) > cov(&p),
                "decode coverage {} <= prefill coverage {}",
                cov(&d), cov(&p));
        // decode fuses the bulk of its graph...
        assert!(cov(&d) * 2 > d.graph.nodes.len(),
                "decode coverage {}/{}", cov(&d), d.graph.nodes.len());
        // ...while prefill keeps every contraction out of regions (a
        // fused matmul would serialise its row blocks)
        for r in &p.regions {
            for i in r.lo..=r.hi {
                assert!(!matches!(p.graph.nodes[i].op, Op::MatMul { .. }),
                        "prefill fused a contraction: {}",
                        p.graph.nodes[i].op.label());
            }
        }
    }

    #[test]
    fn fuse_off_is_the_unfused_oracle() {
        for (entry, batch, t) in
            [(Entry::Prefill, 1, 512), (Entry::Decode, 1, 1),
             (Entry::Decode, 16, 1)] {
            let on = plan_fuse("sim-130m", entry, batch, t, 8,
                               FuseMode::On);
            let off = plan_fuse("sim-130m", entry, batch, t, 8,
                                FuseMode::Off);
            // off: no regions, no elision, fully dense slab
            assert!(off.regions.is_empty());
            assert!(off.elided.iter().all(|&e| !e));
            assert_eq!(off.bytes_elided, 0.0);
            // fusion never perturbs the per-node schedule — the region
            // pass runs after fan-out/tiling/retiering, so the members
            // keep the exact scalar order the oracle runs
            for (a, b) in on.graph.nodes.iter().zip(&off.graph.nodes) {
                assert_eq!(a.sched, b.sched, "{}", a.op.label());
                assert_eq!(a.isa, b.isa, "{}", a.op.label());
            }
            assert_eq!(on.schedule.row_block, off.schedule.row_block);
            assert_eq!(on.schedule.chunk_tile, off.schedule.chunk_tile);
            // the elided slab is never larger than the dense one
            assert!(on.slab_len <= off.slab_len);
        }
    }

    #[test]
    fn fusion_savings_drop_streamed_bytes() {
        // the BENCH_pr9.json gate: planned decode B=1 streamed bytes
        // with fusion on strictly under fusion off, by exactly the
        // bytes the regions elide
        let on = plan_fuse("sim-130m", Entry::Decode, 1, 1, 8,
                           FuseMode::On);
        let off = plan_fuse("sim-130m", Entry::Decode, 1, 1, 8,
                            FuseMode::Off);
        assert!(on.bytes_elided > 0.0);
        assert!(on.stream_bytes < off.stream_bytes);
        assert_eq!(off.stream_bytes - on.stream_bytes, on.bytes_elided);
        // CostInfo stays the entrypoint-level analytic model on both
        assert_eq!(on.cost.bytes_accessed, off.cost.bytes_accessed);
        assert_eq!(on.cost.flops, off.cost.flops);
    }

    #[test]
    fn fusion_elides_single_use_intermediates() {
        // decode B=1: the packed in_proj output, the conv activation
        // and the z gate live and die inside their regions — one
        // scratch row each. The residual stream, the normed copy (read
        // across a region boundary) and the logits must survive.
        let p = plan_fuse("sim-130m", Entry::Decode, 1, 1, 8,
                          FuseMode::On);
        let by_name = |n: &str| {
            p.graph.bufs.iter().position(|b| b.name == n).unwrap()
        };
        for gone in ["zx", "xact", "z"] {
            assert!(p.elided[by_name(gone)], "{gone} should be elided");
        }
        for kept in ["x", "hn", "y", "logits"] {
            assert!(!p.elided[by_name(kept)], "{kept} must survive");
        }
        // elided buffers are backed by exactly one row of scratch
        for (i, b) in p.graph.bufs.iter().enumerate() {
            let (_, len) = p.buf_offsets[i];
            if p.elided[i] {
                assert_eq!(len, b.width, "{}", b.name);
            } else {
                assert_eq!(len, b.len(), "{}", b.name);
            }
        }
    }

    // ------------------------ precision & layout pass (DESIGN §8) -------

    #[test]
    fn bf16_decode_is_priced_and_strictly_wins() {
        // the BENCH acceptance gate (bf16 tok/s > f32 at B ∈ {1, 16})
        // rests on the planner choosing the half-width stream for every
        // decode contraction — which must fall out of the pricing, not a
        // hard-wired rule
        for &b in &[1usize, 16] {
            let p = plan_w("sim-130m", Entry::Decode, b, 1, 8,
                           WeightsDtype::Bf16);
            for node in &p.graph.nodes {
                if let Op::MatMul { repr, .. } = node.op {
                    assert_eq!(repr, WeightRepr::Bf16, "{}",
                               node.op.label());
                }
            }
            assert_eq!(p.schedule.weights_dtype, "bf16");
            assert_eq!(p.schedule.weight_layout, "bf16-rows");
            // the half-width stream must also show up in the priced
            // bytes and the stored invocation cost
            let f = plan_w("sim-130m", Entry::Decode, b, 1, 8,
                           WeightsDtype::F32);
            assert!(p.stream_bytes < f.stream_bytes, "B={b}");
            assert!(p.cost.bytes_accessed < f.cost.bytes_accessed);
            assert!(p.est_seconds < f.est_seconds, "B={b}");
            let ratio = p.stream_bytes / f.stream_bytes;
            if b == 1 {
                // single-slot decode is weight-dominated: the bf16
                // stream roughly halves the bytes per token
                assert!(ratio < 0.75, "B={b}: ratio {ratio}");
            } else {
                // at B=16 per-slot state amortises the weights — the
                // saving shrinks but never vanishes
                assert!(ratio < 0.95, "B={b}: ratio {ratio}");
            }
        }
    }

    #[test]
    fn quantised_decode_is_priced_and_bytes_order_strictly() {
        // the schema-1.7 BENCH gate (q4 < int8 < bf16 < f32 streamed
        // bytes at B=1) rests on the planner pricing each code stream
        // plus its amortised group scales — again priced, not hard-wired
        for &b in &[1usize, 16] {
            let i8p = plan_w("sim-130m", Entry::Decode, b, 1, 8,
                             WeightsDtype::Int8);
            let q4p = plan_w("sim-130m", Entry::Decode, b, 1, 8,
                             WeightsDtype::Q4);
            for (p, want) in [(&i8p, WeightRepr::Int8Group { group: 64 }),
                              (&q4p, WeightRepr::Q4Group { group: 64 })] {
                for node in &p.graph.nodes {
                    if let Op::MatMul { repr, .. } = node.op {
                        assert_eq!(repr, want, "{}", node.op.label());
                    }
                }
            }
            assert_eq!(i8p.schedule.weights_dtype, "int8");
            assert_eq!(i8p.schedule.weight_layout, "int8-g64-rows");
            assert_eq!(q4p.schedule.weights_dtype, "q4");
            assert_eq!(q4p.schedule.weight_layout, "q4-g64-rows");
            let f = plan_w("sim-130m", Entry::Decode, b, 1, 8,
                           WeightsDtype::F32);
            let h = plan_w("sim-130m", Entry::Decode, b, 1, 8,
                           WeightsDtype::Bf16);
            assert!(q4p.stream_bytes < i8p.stream_bytes, "B={b}");
            assert!(i8p.stream_bytes < h.stream_bytes, "B={b}");
            assert!(h.stream_bytes < f.stream_bytes, "B={b}");
            assert!(i8p.cost.bytes_accessed < h.cost.bytes_accessed);
            assert!(q4p.cost.bytes_accessed < i8p.cost.bytes_accessed);
            assert!(i8p.est_seconds < h.est_seconds, "B={b}");
        }
        // the group knob reaches the chosen repr and the layout token
        let cfg = sim_config("sim-130m").unwrap();
        let p = build_plan(&cfg,
                           PlanKey { entry: Entry::Decode, batch: 1, t: 1 },
                           8, WeightsDtype::Int8, 32, Isa::Scalar,
                           FuseMode::On);
        assert_eq!(p.schedule.weight_layout, "int8-g32-rows");
        // a smaller group means more scale bytes, so g32 streams
        // strictly more than g64 while staying under bf16
        let g64 = plan_w("sim-130m", Entry::Decode, 1, 1, 8,
                         WeightsDtype::Int8);
        assert!(p.stream_bytes > g64.stream_bytes);
    }

    #[test]
    fn prefill_stays_f32_under_quantised_knobs() {
        // int8/q4 are decode-only, same as bf16: the prefill graph keeps
        // the exact f32 stream (bitwise-prefill contract of DESIGN §13)
        for dt in [WeightsDtype::Int8, WeightsDtype::Q4] {
            let p = plan_w("sim-130m", Entry::Prefill, 1, 512, 8, dt);
            for node in &p.graph.nodes {
                if let Op::MatMul { repr, .. } = node.op {
                    assert!(matches!(repr, WeightRepr::F32Dense
                                         | WeightRepr::F32Tiled { .. }),
                            "{}: {repr:?}", node.op.label());
                }
            }
        }
    }

    #[test]
    fn prefill_stays_f32_and_tiles_big_weights() {
        // bf16 is decode-only by default: the prefill graph keeps the
        // exact f32 stream even in bf16 mode (parity oracles untouched)
        let p = plan_w("sim-130m", Entry::Prefill, 1, 512, 8,
                       WeightsDtype::Bf16);
        for node in &p.graph.nodes {
            if let Op::MatMul { repr, .. } = node.op {
                assert_ne!(repr, WeightRepr::Bf16, "{}", node.op.label());
            }
        }
        // ...but the layout pass still tiles: every sim-130m prefill
        // weight exceeds the L1 panel budget at 512 rows
        let p = plan("sim-130m", Entry::Prefill, 1, 512, 8);
        let mut tiled = 0;
        for node in &p.graph.nodes {
            if let Op::MatMul { repr, .. } = node.op {
                match repr {
                    WeightRepr::F32Tiled { tile } => {
                        assert!(tile.is_power_of_two());
                        tiled += 1;
                    }
                    r => panic!("{}: expected tiles, got {r:?}",
                                node.op.label()),
                }
            }
        }
        assert!(tiled >= 7, "3 layers x 2 projections + lm head");
        assert!(p.schedule.weight_layout.starts_with("tile"));
        assert_eq!(p.schedule.weights_dtype, "f32");
        // decode widths below TILE_MIN_ROWS stay dense — panel
        // residency has nothing to amortise over
        let d = plan("sim-130m", Entry::Decode, 16, 1, 8);
        for node in &d.graph.nodes {
            if let Op::MatMul { repr, .. } = node.op {
                assert_eq!(repr, WeightRepr::F32Dense);
            }
        }
        assert_eq!(d.schedule.weight_layout, "dense");
    }

    #[test]
    fn tile_for_fits_the_panel_budget() {
        // sim-130m shapes: in_proj k=96 -> 32, out_proj k=192 -> 16,
        // lm head k=96 -> 32 (hand-checked against the golden dump)
        assert_eq!(tile_for(96, 774), 32);
        assert_eq!(tile_for(192, 96), 16);
        assert_eq!(tile_for(96, 512), 32);
        // the panel always fits the budget and never exceeds n
        for (k, n) in [(1usize, 1usize), (64, 516), (128, 64),
                       (4096, 4096), (3, 7)] {
            let t = tile_for(k, n);
            assert!(t <= n.max(8), "k={k} n={n} t={t}");
            assert!(t == 8.min(n.max(1)) || k * t * 4 <= L1_PANEL_BYTES
                    || t <= 8,
                    "k={k} n={n} t={t} busts the budget");
        }
    }

    #[test]
    fn memory_plan_covers_every_buffer() {
        for fuse in [FuseMode::On, FuseMode::Off] {
            let p = plan_fuse("sim-130m", Entry::Prefill, 1, 64, 8,
                              fuse);
            assert_eq!(p.buf_offsets.len(), p.graph.bufs.len());
            assert_eq!(p.elided.len(), p.graph.bufs.len());
            // spans are disjoint and tile the slab exactly: dense
            // buffers first, one scratch row per elided buffer at the
            // tail
            let mut spans: Vec<(usize, usize)> =
                p.buf_offsets.iter().copied().collect();
            spans.sort_unstable();
            let mut end = 0usize;
            for (off, len) in spans {
                assert_eq!(off, end, "offsets are dense and disjoint");
                end = off + len;
            }
            assert_eq!(end, p.slab_len);
            for (i, spec) in p.graph.bufs.iter().enumerate() {
                let want = if p.elided[i] { spec.width }
                           else { spec.len() };
                assert_eq!(p.buf_offsets[i].1, want, "{}", spec.name);
            }
            if fuse == FuseMode::Off {
                assert_eq!(
                    p.slab_len,
                    p.graph.bufs.iter().map(|b| b.len()).sum::<usize>());
            }
        }
    }

    #[test]
    fn plans_are_deterministic() {
        let a = plan("sim-130m", Entry::Prefill, 1, 256, 8);
        let b = plan("sim-130m", Entry::Prefill, 1, 256, 8);
        assert_eq!(a.dump(), b.dump());
        assert_eq!(a.est_seconds, b.est_seconds);
    }

    #[test]
    fn cost_is_hoisted_onto_the_plan() {
        // the plan's stored CostInfo is exactly the analytic model's —
        // computed once at build, not per call
        let cfg = sim_config("sim-130m").unwrap();
        let p = plan("sim-130m", Entry::Prefill, 1, 512, 8);
        let want = analytic_cost(&cfg, "prefill", Some(512), 1);
        assert_eq!(p.cost.flops, want.flops);
        assert_eq!(p.cost.bytes_accessed, want.bytes_accessed);
        assert_eq!(p.cost.transcendentals, want.transcendentals);
        let d = plan("sim-130m", Entry::Decode, 16, 1, 8);
        let want = analytic_cost(&cfg, "decode_step", None, 16);
        assert_eq!(d.cost.flops, want.flops);
    }

    // ------------------------ kernel tier & ISA pricing (DESIGN §11) ----

    #[test]
    fn scalar_tier_plans_are_all_scalar() {
        // the default tier: every node stays scalar, so the plan (and
        // the bitwise-parity contract riding on it) is exactly the
        // pre-kernel-tier output
        for (entry, t) in [(Entry::Prefill, 512), (Entry::Decode, 1)] {
            let p = plan_isa("sim-130m", entry, 1, t, 8, Isa::Scalar);
            assert!(p.graph.nodes.iter()
                .all(|n| n.isa == Isa::Scalar));
            assert_eq!(p.schedule.isa, "scalar");
        }
    }

    #[test]
    fn isa_pricing_retieres_compute_not_bandwidth() {
        // host-independent: build_plan takes the requested tier
        // directly, so this prices AVX2 on any CI machine.
        // prefill at 512 tokens: the projections and lm head are
        // compute-bound (the whole point of the chunked dual form) and
        // the silu-heavy gate norm is transcendental-bound — both
        // retier. The inter-chunk carry scan streams 2·pn bytes per
        // cell for 2·pn flops, far under the per-worker ridge, so
        // wider lanes buy it nothing and it stays scalar.
        let p = plan_isa("sim-130m", Entry::Prefill, 1, 512, 8,
                         Isa::Avx2);
        assert_eq!(p.schedule.isa, "avx2");
        for node in &p.graph.nodes {
            match &node.op {
                Op::MatMul { .. } => {
                    assert_eq!(node.isa, Isa::Avx2, "{}",
                               node.op.label());
                }
                Op::GateNorm { .. } => {
                    assert_eq!(node.isa, Isa::Avx2, "{}",
                               node.op.label());
                }
                Op::ChunkScan { .. } => {
                    assert_eq!(node.isa, Isa::Scalar, "{}",
                               node.op.label());
                }
                op if op.kernel_class().is_none() => {
                    assert_eq!(node.isa, Isa::Scalar, "{}",
                               node.op.label());
                }
                _ => {}
            }
        }
        // and the ISA-priced estimate must actually improve
        let s = plan_isa("sim-130m", Entry::Prefill, 1, 512, 8,
                         Isa::Scalar);
        assert!(p.est_seconds < s.est_seconds);

        // batch-1 decode: every contraction is a weight *stream* — one
        // output row per matrix — so the bandwidth term binds on every
        // tier and the margin keeps the exact scalar kernels
        let d = plan_isa("sim-130m", Entry::Decode, 1, 1, 8, Isa::Avx2);
        assert_eq!(d.schedule.isa, "avx2");
        for node in &d.graph.nodes {
            if matches!(node.op, Op::MatMul { .. }) {
                assert_eq!(node.isa, Isa::Scalar, "{}", node.op.label());
            }
        }
    }

    #[test]
    fn neon_prices_through_the_same_model() {
        // the NEON scales are half AVX2's but the compute-bound prefill
        // contractions still clear the margin
        let p = plan_isa("sim-130m", Entry::Prefill, 1, 512, 8,
                         Isa::Neon);
        assert_eq!(p.schedule.isa, "neon");
        for node in &p.graph.nodes {
            if matches!(node.op, Op::MatMul { .. }) {
                assert_eq!(node.isa, Isa::Neon, "{}", node.op.label());
            }
        }
    }

    #[test]
    fn isa_never_perturbs_the_schedule() {
        // fan-out, fusion, tiling and the dump's schedule constants are
        // chosen ISA-blind: a vector tier may retier nodes but must
        // never shift row_block/chunk_tile/fusion (the golden dump and
        // the tolerance-protocol's like-for-like comparisons rely on
        // matching schedules across tiers)
        for (entry, batch, t) in
            [(Entry::Prefill, 1, 512), (Entry::Decode, 16, 1)] {
            let s = plan_isa("sim-130m", entry, batch, t, 8,
                             Isa::Scalar);
            let v = plan_isa("sim-130m", entry, batch, t, 8,
                             Isa::Avx2);
            assert_eq!(s.schedule.row_block, v.schedule.row_block);
            assert_eq!(s.schedule.chunk_tile, v.schedule.chunk_tile);
            // region *membership* is ISA-blind (the recorded region
            // tier may legitimately differ — it mirrors the members)
            let ranges = |p: &Plan| p.regions.iter()
                .map(|r| (r.lo, r.hi)).collect::<Vec<_>>();
            assert_eq!(ranges(&s), ranges(&v));
            assert_eq!(s.schedule.weight_layout, v.schedule.weight_layout);
            for (a, b) in s.graph.nodes.iter().zip(&v.graph.nodes) {
                assert_eq!(a.sched, b.sched, "{}", a.op.label());
            }
        }
    }

    #[test]
    fn est_time_orders_with_work() {
        let small = plan("sim-130m", Entry::Prefill, 1, 64, 8);
        let big = plan("sim-130m", Entry::Prefill, 1, 512, 8);
        assert!(big.est_seconds > small.est_seconds);
        let b1 = plan("sim-130m", Entry::Decode, 1, 1, 8);
        let b16 = plan("sim-130m", Entry::Decode, 16, 1, 8);
        assert!(b16.est_seconds > b1.est_seconds);
        // but far less than 16x — the fused batch amortises weights
        assert!(b16.est_seconds < 16.0 * b1.est_seconds);
    }
}
