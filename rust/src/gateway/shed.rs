//! Admission control: queue-depth shedding with an estimated-delay
//! `Retry-After`.
//!
//! The gateway answers `429` instead of stalling the socket when the
//! pool's admission queue is already past the configured depth. The
//! retry hint is the estimated time for the queue to drain ahead of the
//! caller: ceil(queued / pool slots) service rounds, each costing the
//! observed median end-to-end latency (1 s fallback before any request
//! has completed). Deliberately coarse — its job is to spread retries,
//! not to promise a slot.

/// Queue-depth admission policy.
pub struct ShedPolicy {
    /// shed when the pool-wide queue depth EXCEEDS this (0 = shed as
    /// soon as anything is queued; admitted/decoding requests never
    /// count against it)
    pub max_queue_depth: usize,
}

impl ShedPolicy {
    /// Should a new request be shed given the current queue depth?
    pub fn should_shed(&self, queued: u64) -> bool {
        queued > self.max_queue_depth as u64
    }

    /// Estimated seconds until the present queue has drained (the
    /// `Retry-After` value). Always at least 1.
    pub fn retry_after_s(queued: u64, capacity: usize, e2e_p50_s: f64)
        -> u64 {
        let per = if e2e_p50_s.is_finite() && e2e_p50_s > 0.0 {
            e2e_p50_s
        } else {
            1.0
        };
        let rounds = (queued as f64 / capacity.max(1) as f64).ceil();
        ((rounds * per).ceil() as u64).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sheds_strictly_above_the_limit() {
        let p = ShedPolicy { max_queue_depth: 2 };
        assert!(!p.should_shed(0));
        assert!(!p.should_shed(2));
        assert!(p.should_shed(3));
        // depth 0: one queued request is already too many
        let p0 = ShedPolicy { max_queue_depth: 0 };
        assert!(!p0.should_shed(0));
        assert!(p0.should_shed(1));
    }

    #[test]
    fn retry_after_scales_with_queue_and_capacity() {
        // 8 queued, 4 slots, 2 s median → 2 rounds × 2 s = 4 s
        assert_eq!(ShedPolicy::retry_after_s(8, 4, 2.0), 4);
        // more capacity drains faster
        assert_eq!(ShedPolicy::retry_after_s(8, 8, 2.0), 2);
        // no latency signal yet → 1 s per round fallback
        assert_eq!(ShedPolicy::retry_after_s(3, 1, 0.0), 3);
        // never less than one second, capacity never divides by zero
        assert_eq!(ShedPolicy::retry_after_s(1, 0, 0.001), 1);
        assert!(ShedPolicy::retry_after_s(0, 4, 5.0) >= 1);
        // a NaN latency estimate falls back instead of poisoning the hint
        assert_eq!(ShedPolicy::retry_after_s(2, 2, f64::NAN), 1);
    }
}
