//! Server-Sent Events framing for streaming completions.
//!
//! `stream:true` maps the engine's v2 per-step delta semantics onto SSE:
//! one `data:` event per delta frame, a final chunk carrying the finish
//! reason + usage, then the OpenAI-style `data: [DONE]` terminator. SSE
//! responses are EOF-delimited (`Connection: close`) — no chunked
//! transfer coding, so the framing stays trivially verifiable.

/// Terminal frame every stream ends with.
pub const DONE_FRAME: &str = "data: [DONE]\n\n";

/// Response head for an SSE stream. No `Content-Length`: the body ends
/// when the connection closes.
pub const PREAMBLE: &str =
    "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
     Cache-Control: no-cache\r\nConnection: close\r\n\r\n";

/// Encode one SSE event: each payload line prefixed `data: `, the frame
/// terminated by a blank line. (JSON payloads are single-line under
/// `util::json`, but multi-line payloads still frame correctly.)
pub fn event(payload: &str) -> String {
    let mut out = String::with_capacity(payload.len() + 16);
    for line in payload.split('\n') {
        out.push_str("data: ");
        out.push_str(line);
        out.push('\n');
    }
    out.push('\n');
    out
}

/// Extract the `data:` payloads from a raw SSE body (test-side decoder;
/// the `[DONE]` sentinel is returned like any other payload).
pub fn decode(body: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur: Option<String> = None;
    for line in body.split('\n') {
        if let Some(rest) = line.strip_prefix("data: ") {
            match &mut cur {
                Some(c) => {
                    c.push('\n');
                    c.push_str(rest);
                }
                None => cur = Some(rest.to_string()),
            }
        } else if line.is_empty() {
            if let Some(c) = cur.take() {
                out.push(c);
            }
        }
    }
    if let Some(c) = cur.take() {
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_frames_and_decodes() {
        let e = event("{\"x\":1}");
        assert_eq!(e, "data: {\"x\":1}\n\n");
        let multi = event("a\nb");
        assert_eq!(multi, "data: a\ndata: b\n\n");
        let body = format!("{}{}{}", event("one"), event("two"),
                           DONE_FRAME);
        assert_eq!(decode(&body),
                   vec!["one".to_string(), "two".to_string(),
                        "[DONE]".to_string()]);
        assert_eq!(decode(&multi), vec!["a\nb".to_string()]);
    }
}
