//! Prometheus text exposition (format 0.0.4), rendered by hand.
//!
//! `GET /metrics` serves one scrape assembled from `Metrics::snapshot`
//! per replica (labelled `{replica="i"}`), pool-level gauges from the
//! shared [`InFlightGauge`](crate::coordinator::InFlightGauge), the
//! gateway's own request/shed counters, and the cross-frontend
//! connection-error breakdown (`m2_conn_errors_total{kind=...}`). The
//! builder emits each family's `# HELP`/`# TYPE` exactly once, in first-
//! sample order, which is what makes the output valid exposition format.

use crate::coordinator::{ConnErrorKind, ConnErrors, Router};
use crate::util::stats::LogHistogram;

/// Incremental exposition builder.
#[derive(Default)]
pub struct Prom {
    out: String,
    seen: Vec<String>,
}

impl Prom {
    pub fn new() -> Prom {
        Prom::default()
    }

    fn family(&mut self, name: &str, help: &str, kind: &str) {
        if !self.seen.iter().any(|s| s == name) {
            self.seen.push(name.to_string());
            self.out.push_str("# HELP ");
            self.out.push_str(name);
            self.out.push(' ');
            self.out.push_str(help);
            self.out.push_str("\n# TYPE ");
            self.out.push_str(name);
            self.out.push(' ');
            self.out.push_str(kind);
            self.out.push('\n');
        }
    }

    /// One `name{labels} value` line, no HELP/TYPE bookkeeping (the
    /// histogram renderer emits `_bucket`/`_sum`/`_count` samples under
    /// the base family's single TYPE line).
    fn line(&mut self, name: &str, labels: &[(&str, String)],
            value: f64) {
        let v = if value.is_finite() { value } else { 0.0 };
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, val)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                self.out.push_str(val);
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&v.to_string());
        self.out.push('\n');
    }

    /// Append one sample. Non-finite values are clamped to 0 (the
    /// exposition format has no NaN).
    pub fn sample(&mut self, name: &str, help: &str, kind: &str,
                  labels: &[(&str, String)], value: f64) {
        self.family(name, help, kind);
        self.line(name, labels, value);
    }

    /// Append one Prometheus histogram: cumulative `_bucket{le=...}`
    /// samples at `les` boundaries (projected from the log-bucketed
    /// [`LogHistogram`] via [`LogHistogram::count_le`]), the mandatory
    /// `le="+Inf"` bucket, then `_sum` and `_count`. The family's
    /// HELP/TYPE pair is emitted once under the base `name`, which is
    /// how the text format declares all three sample suffixes.
    pub fn histogram(&mut self, name: &str, help: &str,
                     labels: &[(&str, String)], les: &[f64],
                     h: &LogHistogram) {
        self.family(name, help, "histogram");
        let bucket = format!("{name}_bucket");
        let mut l: Vec<(&str, String)> = labels.to_vec();
        l.push(("le", String::new()));
        for &le in les {
            l.last_mut().unwrap().1 = le.to_string();
            self.line(&bucket, &l, h.count_le(le) as f64);
        }
        l.last_mut().unwrap().1 = "+Inf".to_string();
        self.line(&bucket, &l, h.total as f64);
        self.line(&format!("{name}_sum"), labels, h.sum);
        self.line(&format!("{name}_count"), labels, h.total as f64);
    }

    pub fn render(self) -> String {
        self.out
    }
}

/// Per-replica + pool-level families for one engine pool. The gateway
/// appends its own `m2_gateway_*` samples after this.
pub fn pool_samples(p: &mut Prom, router: &Router) {
    for i in 0..router.n_replicas() {
        let s = router.replica(i).metrics.snapshot();
        let l: &[(&str, String)] = &[("replica", i.to_string())];
        p.sample("m2_requests_submitted_total",
                 "requests submitted to this replica", "counter", l,
                 s.submitted as f64);
        p.sample("m2_requests_admitted_total",
                 "requests that left the admission queue", "counter", l,
                 s.admitted as f64);
        p.sample("m2_requests_completed_total",
                 "requests finished successfully", "counter", l,
                 s.completed as f64);
        p.sample("m2_requests_failed_total",
                 "requests finished with an error", "counter", l,
                 s.failed as f64);
        p.sample("m2_requests_cancelled_total",
                 "requests cancelled mid-flight", "counter", l,
                 s.cancelled as f64);
        p.sample("m2_queue_depth",
                 "requests waiting for a decode slot", "gauge", l,
                 s.queue_depth as f64);
        p.sample("m2_in_flight",
                 "requests submitted but not yet settled", "gauge", l,
                 s.in_flight as f64);
        p.sample("m2_tokens_generated_total",
                 "tokens sampled", "counter", l,
                 s.tokens_generated as f64);
        p.sample("m2_prefill_tokens_total",
                 "prompt tokens actually prefilled (prefix-cache hits \
                  subtract the reused segment)", "counter", l,
                 s.prefill_tokens as f64);
        p.sample("m2_decode_steps_total",
                 "batched decode steps", "counter", l,
                 s.decode_steps as f64);
        p.sample("m2_ttft_seconds_p50",
                 "median time to first token", "gauge", l, s.ttft_p50);
        p.sample("m2_ttft_seconds_p99",
                 "p99 time to first token", "gauge", l, s.ttft_p99);
        p.sample("m2_e2e_seconds_p50",
                 "median request latency", "gauge", l, s.e2e_p50);
        p.sample("m2_e2e_seconds_p99",
                 "p99 request latency", "gauge", l, s.e2e_p99);
        p.sample("m2_prefix_cache_hits_total",
                 "prompt-prefix cache hits", "counter", l,
                 s.prefix_hits as f64);
        p.sample("m2_prefix_cache_misses_total",
                 "prompt-prefix cache misses", "counter", l,
                 s.prefix_misses as f64);
        p.sample("m2_prefix_cache_evictions_total",
                 "prompt-prefix cache evictions", "counter", l,
                 s.prefix_evictions as f64);
        p.sample("m2_prefix_cache_insertions_total",
                 "prompt-prefix cache insertions", "counter", l,
                 s.prefix_insertions as f64);
        p.sample("m2_prefix_cache_bytes",
                 "prompt-prefix cache residency", "gauge", l,
                 s.prefix_bytes as f64);
        p.sample("m2_prefix_cache_entries",
                 "prompt-prefix cache entry count", "gauge", l,
                 s.prefix_entries as f64);
        // weight-stream identity (DESIGN.md §13): the planner's
        // modelled B=1 decode bytes/token, labelled by stream dtype so
        // dashboards can watch the quantised saving per replica
        if !s.weights_dtype.is_empty() {
            let wl: &[(&str, String)] = &[
                ("replica", i.to_string()),
                ("dtype", s.weights_dtype.clone()),
            ];
            p.sample("m2_bytes_streamed_per_token",
                     "modelled weight+state bytes streamed per decoded \
                      token at batch 1, by weight-stream dtype",
                     "gauge", wl, s.bytes_streamed_per_token);
        }
    }
    p.sample("m2_in_flight_total",
             "in-flight requests across all replicas (shared gauge)",
             "gauge", &[], router.in_flight() as f64);
    p.sample("m2_pool_slots",
             "decode slots across all replicas", "gauge", &[],
             router.total_slots() as f64);
}

/// The cross-frontend connection-error breakdown (shared between the
/// wire server and the gateway, so there is deliberately no frontend
/// label — one process-wide count per kind).
pub fn conn_error_samples(p: &mut Prom, errors: &ConnErrors) {
    for k in ConnErrorKind::ALL {
        p.sample("m2_conn_errors_total",
                 "connections ended by an error, by kind", "counter",
                 &[("kind", k.as_str().to_string())],
                 errors.get(k) as f64);
    }
}

/// Validate exposition-format invariants on rendered output (test
/// helper, also used by the integration suite): every non-comment line
/// is `name[{labels}] value` with a finite value, and every metric name
/// was introduced by HELP + TYPE.
pub fn validate_exposition(text: &str) -> Result<(), String> {
    let mut declared: Vec<&str> = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let mut it = rest.split_whitespace();
            let tag = it.next().unwrap_or("");
            let name = it.next().unwrap_or("");
            if tag == "TYPE" {
                if declared.contains(&name) {
                    return Err(format!("duplicate TYPE for {name}"));
                }
                declared.push(name);
            }
            continue;
        }
        let (name_part, value) = line.rsplit_once(' ')
            .ok_or_else(|| format!("no value in line: {line}"))?;
        let name = name_part.split('{').next().unwrap_or(name_part);
        // histogram families declare the base name once; their samples
        // carry the _bucket/_sum/_count suffixes
        let base = ["_bucket", "_sum", "_count"].iter()
            .find_map(|s| name.strip_suffix(s))
            .unwrap_or(name);
        if !declared.contains(&name) && !declared.contains(&base) {
            return Err(format!("sample before TYPE: {name}"));
        }
        let v: f64 = value.parse()
            .map_err(|_| format!("bad value in line: {line}"))?;
        if !v.is_finite() {
            return Err(format!("non-finite value in line: {line}"));
        }
        if name_part.contains('{') && !name_part.ends_with('}') {
            return Err(format!("unterminated labels in line: {line}"));
        }
    }
    if declared.is_empty() {
        return Err("empty exposition".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn help_type_emitted_once_per_family() {
        let mut p = Prom::new();
        p.sample("m2_x_total", "x", "counter",
                 &[("replica", "0".to_string())], 1.0);
        p.sample("m2_x_total", "x", "counter",
                 &[("replica", "1".to_string())], 2.0);
        p.sample("m2_y", "y", "gauge", &[], 0.5);
        let out = p.render();
        assert_eq!(out.matches("# TYPE m2_x_total counter").count(), 1);
        assert!(out.contains("m2_x_total{replica=\"0\"} 1\n"));
        assert!(out.contains("m2_x_total{replica=\"1\"} 2\n"));
        assert!(out.contains("m2_y 0.5\n"));
        validate_exposition(&out).unwrap();
    }

    #[test]
    fn non_finite_values_are_clamped() {
        let mut p = Prom::new();
        p.sample("m2_nan", "n", "gauge", &[], f64::NAN);
        p.sample("m2_inf", "i", "gauge", &[], f64::INFINITY);
        let out = p.render();
        assert!(out.contains("m2_nan 0\n"));
        assert!(out.contains("m2_inf 0\n"));
        validate_exposition(&out).unwrap();
    }

    #[test]
    fn conn_error_kinds_all_present() {
        let errors = ConnErrors::new();
        errors.record(crate::coordinator::ConnErrorKind::Protocol);
        let mut p = Prom::new();
        conn_error_samples(&mut p, &errors);
        let out = p.render();
        assert!(out.contains("m2_conn_errors_total{kind=\"io\"} 0\n"));
        assert!(out.contains(
            "m2_conn_errors_total{kind=\"protocol\"} 1\n"));
        assert!(out.contains(
            "m2_conn_errors_total{kind=\"too_large\"} 0\n"));
        validate_exposition(&out).unwrap();
    }

    #[test]
    fn histogram_renders_valid_cumulative_buckets() {
        let mut h = LogHistogram::new();
        for i in 1..=50 {
            h.record(i as f64 * 1e-3); // 1ms .. 50ms
        }
        let mut p = Prom::new();
        for route in ["completions", "metrics"] {
            p.histogram("m2_http_request_seconds",
                        "HTTP request latency by route",
                        &[("route", route.to_string())],
                        &[0.005, 0.05, 1.0], &h);
        }
        let out = p.render();
        // one TYPE for the family, shared by every route's samples
        assert_eq!(out.matches(
            "# TYPE m2_http_request_seconds histogram").count(), 1);
        assert!(out.contains("m2_http_request_seconds_bucket\
                              {route=\"completions\",le=\"+Inf\"} 50\n"));
        assert!(out.contains("m2_http_request_seconds_count\
                              {route=\"metrics\"} 50\n"));
        // buckets are cumulative: each boundary's count never exceeds
        // the next one's, and +Inf equals _count
        let count_at = |le: &str| -> f64 {
            out.lines()
                .find(|l| l.contains("route=\"completions\"")
                          && l.contains(&format!("le=\"{le}\"")))
                .and_then(|l| l.rsplit(' ').next())
                .and_then(|v| v.parse().ok())
                .unwrap()
        };
        assert!(count_at("0.005") <= count_at("0.05"));
        assert!(count_at("0.05") <= count_at("1"));
        assert!(count_at("1") <= count_at("+Inf"));
        validate_exposition(&out).unwrap();
    }

    #[test]
    fn validator_rejects_malformed_expositions() {
        assert!(validate_exposition("m2_x 1\n").is_err()); // no TYPE
        assert!(validate_exposition("").is_err());
        let dup = "# HELP m2_x x\n# TYPE m2_x gauge\n\
                   # HELP m2_x x\n# TYPE m2_x gauge\nm2_x 1\n";
        assert!(validate_exposition(dup).is_err());
        let ok = "# HELP m2_x x\n# TYPE m2_x gauge\nm2_x 1\n";
        assert!(validate_exposition(ok).is_ok());
    }
}
