//! OpenAI-compatible request/response shapes for `/v1/completions`.
//!
//! The subset that maps cleanly onto [`GenerateParams`]: `prompt`
//! (string, or a one-element array), `max_tokens`, `temperature`,
//! `top_p`, `top_k`, `seed`, `stop` (string or array), `echo`, `stream`.
//! Absent sampling fields are NOT defaulted onto the params — an absent
//! `temperature` keeps greedy decoding, so the same prompt through HTTP
//! and through the wire protocol samples bitwise-identically (the parity
//! the integration suite pins). Each `choices[0]` carries a non-standard
//! `token_ids` array precisely to make that parity testable end-to-end.

use crate::coordinator::{FinishReason, GenerateParams};
use crate::util::json::Json;

/// One parsed completion request.
pub struct CompletionRequest {
    pub prompt: String,
    pub params: GenerateParams,
    pub stream: bool,
    pub model: Option<String>,
}

/// Parse a `/v1/completions` body. Errors are client-facing messages
/// (the gateway wraps them in the OpenAI error envelope with a 400).
pub fn parse_completion(j: &Json) -> Result<CompletionRequest, String> {
    let pj = j.get("prompt")
        .ok_or_else(|| "missing required field: prompt".to_string())?;
    let prompt = if let Some(s) = pj.as_str() {
        s.to_string()
    } else if let Some(a) = pj.as_arr() {
        if a.len() != 1 {
            return Err("prompt arrays must contain exactly one string \
                        (batched completions are not supported)".into());
        }
        a[0].as_str()
            .ok_or_else(|| "prompt array elements must be strings"
                        .to_string())?
            .to_string()
    } else {
        return Err("prompt must be a string".into());
    };
    if j.get("n").and_then(Json::as_u64).unwrap_or(1) != 1 {
        return Err("n must be 1 (parallel choices are not supported)"
                   .into());
    }
    let mut p = GenerateParams::new()
        .max_new_tokens(j.get("max_tokens").and_then(Json::as_u64)
                        .unwrap_or(16) as usize)
        .seed(j.get("seed").and_then(Json::as_u64).unwrap_or(0));
    if let Some(k) = j.get("top_k").and_then(Json::as_u64) {
        p = p.top_k(k as usize);
    }
    if let Some(tp) = j.get("top_p").and_then(Json::as_f64) {
        p = p.top_p(tp as f32);
    }
    if let Some(t) = j.get("temperature").and_then(Json::as_f64) {
        // only when present: setting any temperature switches the
        // sampler resolution away from greedy (see GenerateParams)
        p = p.temperature(t as f32);
    }
    match j.get("stop") {
        Some(s) => {
            if let Some(one) = s.as_str() {
                p = p.stop_string(one);
            } else if let Some(arr) = s.as_arr() {
                for v in arr {
                    match v.as_str() {
                        Some(ss) => p = p.stop_string(ss),
                        None => return Err("stop array elements must be \
                                            strings".into()),
                    }
                }
            } else {
                return Err("stop must be a string or an array of \
                            strings".into());
            }
        }
        None => {}
    }
    if j.get("echo").and_then(Json::as_bool).unwrap_or(false) {
        p = p.echo(true);
    }
    Ok(CompletionRequest {
        prompt,
        params: p,
        stream: j.get("stream").and_then(Json::as_bool).unwrap_or(false),
        model: j.get("model").and_then(Json::as_str)
            .map(|s| s.to_string()),
    })
}

/// OpenAI finish_reason vocabulary: both stop-token and stop-string
/// terminations surface as `"stop"`.
pub fn finish_reason(r: &FinishReason) -> &'static str {
    match r {
        FinishReason::Length => "length",
        FinishReason::StopToken | FinishReason::StopString => "stop",
        FinishReason::Cancelled => "cancelled",
    }
}

fn token_arr(token_ids: &[i32]) -> Json {
    Json::Arr(token_ids.iter().map(|&t| Json::num(t as f64)).collect())
}

pub fn usage_json(prompt_tokens: usize, completion_tokens: usize) -> Json {
    Json::obj(vec![
        ("prompt_tokens", Json::num(prompt_tokens as f64)),
        ("completion_tokens", Json::num(completion_tokens as f64)),
        ("total_tokens",
         Json::num((prompt_tokens + completion_tokens) as f64)),
    ])
}

/// Non-streaming completion response.
#[allow(clippy::too_many_arguments)]
pub fn completion_json(id: &str, model: &str, created: u64, text: &str,
                       token_ids: &[i32], finish: &str,
                       prompt_tokens: usize, completion_tokens: usize)
    -> Json {
    Json::obj(vec![
        ("id", Json::str(id)),
        ("object", Json::str("text_completion")),
        ("created", Json::num(created as f64)),
        ("model", Json::str(model)),
        ("choices", Json::Arr(vec![Json::obj(vec![
            ("text", Json::str(text)),
            ("index", Json::num(0.0)),
            ("logprobs", Json::Null),
            ("token_ids", token_arr(token_ids)),
            ("finish_reason", Json::str(finish)),
        ])])),
        ("usage", usage_json(prompt_tokens, completion_tokens)),
    ])
}

/// One streaming chunk: a delta while `finish` is `None`, the terminal
/// chunk (empty text, finish reason + usage) otherwise.
pub fn chunk_json(id: &str, model: &str, created: u64, text: &str,
                  token_ids: &[i32], finish: Option<&str>,
                  usage: Option<Json>) -> Json {
    let mut fields = vec![
        ("id", Json::str(id)),
        ("object", Json::str("text_completion")),
        ("created", Json::num(created as f64)),
        ("model", Json::str(model)),
        ("choices", Json::Arr(vec![Json::obj(vec![
            ("text", Json::str(text)),
            ("index", Json::num(0.0)),
            ("logprobs", Json::Null),
            ("token_ids", token_arr(token_ids)),
            ("finish_reason", match finish {
                Some(f) => Json::str(f),
                None => Json::Null,
            }),
        ])])),
    ];
    if let Some(u) = usage {
        fields.push(("usage", u));
    }
    Json::obj(fields)
}

/// `GET /v1/models` body.
pub fn models_json(model: &str) -> Json {
    Json::obj(vec![
        ("object", Json::str("list")),
        ("data", Json::Arr(vec![Json::obj(vec![
            ("id", Json::str(model)),
            ("object", Json::str("model")),
            ("owned_by", Json::str("mamba2-serve")),
        ])])),
    ])
}

/// OpenAI error envelope.
pub fn error_json(kind: &str, msg: &str) -> Json {
    Json::obj(vec![("error", Json::obj(vec![
        ("message", Json::str(msg)),
        ("type", Json::str(kind)),
        ("code", Json::Null),
    ]))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Sampling;

    #[test]
    fn absent_sampling_fields_stay_greedy() {
        let j = Json::parse(
            r#"{"model":"m","prompt":"hi","max_tokens":8}"#).unwrap();
        let r = parse_completion(&j).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.params.max_new_tokens, 8);
        assert!(matches!(r.params.sampling(), Sampling::Greedy));
        assert!(!r.stream);
        assert_eq!(r.model.as_deref(), Some("m"));
    }

    #[test]
    fn sampling_fields_map_through() {
        let j = Json::parse(
            r#"{"prompt":["p"],"temperature":0.7,"top_p":0.9,
                "seed":3,"stop":["\n\n","END"],"stream":true,
                "echo":true}"#).unwrap();
        let r = parse_completion(&j).unwrap();
        assert!(r.stream);
        assert!(r.params.echo);
        assert_eq!(r.params.stop_strings,
                   vec!["\n\n".to_string(), "END".to_string()]);
        assert!(matches!(r.params.sampling(), Sampling::TopP { .. }));
    }

    #[test]
    fn rejects_what_the_engine_cannot_serve() {
        for body in [
            r#"{"max_tokens":4}"#,                  // no prompt
            r#"{"prompt":["a","b"]}"#,              // batched array
            r#"{"prompt":"x","n":2}"#,              // parallel choices
            r#"{"prompt":7}"#,                      // non-string prompt
            r#"{"prompt":"x","stop":7}"#,           // bad stop type
        ] {
            let j = Json::parse(body).unwrap();
            assert!(parse_completion(&j).is_err(), "accepted: {body}");
        }
    }

    #[test]
    fn response_shapes() {
        let c = completion_json("cmpl-1", "m", 123, "out", &[5, 6],
                                "length", 3, 2);
        let s = c.to_string();
        assert!(s.contains("\"object\":\"text_completion\""));
        assert!(s.contains("\"token_ids\":[5,6]"));
        assert!(s.contains("\"total_tokens\":5"));
        let ch = chunk_json("cmpl-1", "m", 123, "d", &[5], None, None);
        assert!(ch.to_string().contains("\"finish_reason\":null"));
        let last = chunk_json("cmpl-1", "m", 123, "", &[],
                              Some("stop"), Some(usage_json(1, 1)));
        let ls = last.to_string();
        assert!(ls.contains("\"finish_reason\":\"stop\""));
        assert!(ls.contains("\"usage\""));
        assert!(models_json("m").to_string().contains("\"id\":\"m\""));
        assert!(error_json("invalid_request_error", "boom").to_string()
                .contains("\"type\":\"invalid_request_error\""));
    }
}
