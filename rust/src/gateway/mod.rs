//! HTTP serving gateway (DESIGN.md §10): OpenAI-compatible
//! `/v1/completions` over hand-rolled HTTP/1.1, in front of the same
//! [`Router`] the wire server drives.
//!
//! * `http`   — request/response framing (size-capped, keep-alive)
//! * `openai` — completions request/response shapes
//! * `sse`    — `stream:true` → `text/event-stream` over v2 deltas
//! * `pool`   — N engine replicas sharing one in-flight gauge
//! * `shed`   — queue-depth admission control (`429` + `Retry-After`)
//! * `prom`   — `/metrics` Prometheus text exposition
//!
//! Request lifecycle: accept (shared [`serve_listener`] plumbing with
//! the wire server) → parse → route → admission check → tokenize with
//! the SAME tokenizer as the wire path (so the prefix cache, keyed on
//! token ids, hits identically for identical prompts) → drive the
//! engine through [`server::pump_generate`] — the same delta pump the
//! wire protocol uses, which is what makes HTTP and wire token ids
//! bitwise-identical. Client disconnects propagate to engine
//! cancellation: blocking requests are probed every few tokens,
//! streaming requests notice on the failed SSE write; either way the
//! decode slot frees mid-generation.
//!
//! Graceful drain: `GatewayHandle::drain` (or `POST /admin/drain`) stops
//! admission (`503` on new completions, `503` on `/healthz`), the accept
//! loop exits, and the connection pool's drop joins every in-flight
//! handler — admitted streams run to completion before drain returns.

pub mod http;
pub mod openai;
pub mod pool;
pub mod prom;
pub mod shed;
pub mod sse;

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use crate::coordinator::{ConnErrorKind, ConnErrors, Router};
use crate::eval::Tokenizer;
use crate::server::{pump_generate, serve_listener};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::stats::LogHistogram;

use shed::ShedPolicy;

pub struct GatewayConfig {
    /// model id served (and pinned: requests naming another model 404)
    pub model: String,
    /// connection-handler threads (each keep-alive connection holds one
    /// while it is being served)
    pub threads: usize,
    /// shed when the pool-wide admission queue exceeds this depth
    pub max_queue_depth: usize,
    /// idle keep-alive read timeout; also the bound on how long drain
    /// waits for idle connections
    pub keep_alive: Duration,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            model: "sim-130m".into(),
            threads: 8,
            max_queue_depth: 64,
            keep_alive: Duration::from_secs(5),
        }
    }
}

/// Gateway-level counters (engine-level ones live in `Metrics`).
#[derive(Default)]
pub struct GatewayMetrics {
    /// completion requests admitted past the shed check
    pub requests: AtomicU64,
    /// completion requests answered `429`
    pub shed: AtomicU64,
    /// every HTTP request dispatched, all routes
    pub http_requests: AtomicU64,
    /// requests currently inside a handler
    pub active: AtomicU64,
    /// per-route request-latency histograms, rendered on `/metrics` as
    /// `m2_http_request_seconds{route=...}` buckets (PR 9). Routes
    /// appear on first hit; one mutex, recorded once per dispatch (the
    /// same off-hot-loop pattern as `coordinator::Metrics`). For SSE
    /// completions the latency spans the whole stream — route
    /// histograms time the handler, TTFT/e2e stay the engine's.
    route_hist: Mutex<Vec<(&'static str, LogHistogram)>>,
}

impl GatewayMetrics {
    /// Record one dispatched request against its route's histogram.
    pub fn record_route(&self, route: &'static str, secs: f64) {
        let mut hists = self.route_hist.lock().unwrap();
        match hists.iter_mut().find(|(r, _)| *r == route) {
            Some((_, h)) => h.record(secs),
            None => {
                let mut h = LogHistogram::new();
                h.record(secs);
                hists.push((route, h));
            }
        }
    }
}

/// The `route` label value for one request path: the fixed route set
/// plus `other` for 404s, so label cardinality is bounded no matter
/// what clients probe.
fn route_label(path: &str) -> &'static str {
    match path {
        "/healthz" => "healthz",
        "/metrics" => "metrics",
        "/v1/models" => "models",
        "/v1/completions" => "completions",
        "/admin/drain" => "admin_drain",
        _ => "other",
    }
}

/// Histogram boundaries for `m2_http_request_seconds`: 1ms–60s in
/// roughly 5× steps — wide enough that a full SSE generation lands in
/// a finite bucket, fine enough to separate `/healthz` from prefill.
const ROUTE_LATENCY_LE: [f64; 8] =
    [0.001, 0.005, 0.025, 0.1, 0.5, 2.5, 10.0, 60.0];

struct GwInner {
    router: Arc<Router>,
    tok: Arc<Tokenizer>,
    cfg: GatewayConfig,
    metrics: GatewayMetrics,
    conn_errors: Arc<ConnErrors>,
    shed: ShedPolicy,
    /// set = draining: refuse new work, let the accept loop exit
    stop: Arc<AtomicBool>,
    next_id: AtomicU64,
}

#[derive(Clone)]
pub struct Gateway {
    inner: Arc<GwInner>,
}

impl Gateway {
    pub fn new(router: Arc<Router>, tok: Arc<Tokenizer>,
               cfg: GatewayConfig) -> Gateway {
        Gateway::with_conn_errors(router, tok, cfg,
                                  Arc::new(ConnErrors::new()))
    }

    /// Share the connection-error breakdown with the wire server (see
    /// `Server::with_conn_errors`): one process-wide count per kind.
    pub fn with_conn_errors(router: Arc<Router>, tok: Arc<Tokenizer>,
                            cfg: GatewayConfig,
                            conn_errors: Arc<ConnErrors>) -> Gateway {
        let shed = ShedPolicy { max_queue_depth: cfg.max_queue_depth };
        Gateway {
            inner: Arc::new(GwInner {
                router, tok, cfg,
                metrics: GatewayMetrics::default(),
                conn_errors, shed,
                stop: Arc::new(AtomicBool::new(false)),
                next_id: AtomicU64::new(1),
            }),
        }
    }

    pub fn requests_total(&self) -> u64 {
        self.inner.metrics.requests.load(Ordering::Relaxed)
    }

    pub fn shed_total(&self) -> u64 {
        self.inner.metrics.shed.load(Ordering::Relaxed)
    }

    pub fn n_replicas(&self) -> usize {
        self.inner.router.n_replicas()
    }

    /// Serve on the calling thread until drained (see
    /// [`GatewayHandle::drain`]). Returning implies every accepted
    /// connection has been handled to completion.
    pub fn serve(&self, addr: &str,
                 on_bound: impl FnOnce(SocketAddr)) -> Result<()> {
        let inner = Arc::clone(&self.inner);
        let stop = Arc::clone(&self.inner.stop);
        serve_listener(addr, self.inner.cfg.threads, Some(stop),
                       on_bound,
                       move |stream, peer| {
                           handle_conn(&inner, stream, peer);
                       })
    }

    /// Spawn the accept loop on its own thread and return a handle once
    /// the listener is bound (port 0 supported).
    pub fn start(&self, addr: &str) -> Result<GatewayHandle> {
        let (txa, rxa) = mpsc::channel();
        let gw = self.clone();
        let addr = addr.to_string();
        let join = thread::Builder::new()
            .name("gateway-accept".into())
            .spawn(move || gw.serve(&addr, |a| {
                let _ = txa.send(a);
            }))?;
        match rxa.recv() {
            Ok(a) => Ok(GatewayHandle {
                addr: a,
                inner: Arc::clone(&self.inner),
                join,
            }),
            Err(_) => {
                // serve() failed before binding: surface its error
                match join.join() {
                    Ok(Err(e)) => Err(e),
                    _ => crate::bail!("gateway failed to start"),
                }
            }
        }
    }
}

pub struct GatewayHandle {
    addr: SocketAddr,
    inner: Arc<GwInner>,
    join: thread::JoinHandle<Result<()>>,
}

impl GatewayHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn requests_total(&self) -> u64 {
        self.inner.metrics.requests.load(Ordering::Relaxed)
    }

    pub fn shed_total(&self) -> u64 {
        self.inner.metrics.shed.load(Ordering::Relaxed)
    }

    /// Graceful drain: stop accepting, refuse new completions with
    /// `503`, finish every in-flight request (streams run to their
    /// `[DONE]`), then return. Idle keep-alive connections are released
    /// by their read timeout, so drain is bounded by
    /// `keep_alive + the longest admitted request`.
    pub fn drain(self) -> Result<()> {
        self.inner.stop.store(true, Ordering::Relaxed);
        match self.join.join() {
            Ok(r) => r,
            Err(_) => crate::bail!("gateway accept thread panicked"),
        }
    }
}

/// Non-destructive peer-liveness probe (single-owner variant of the wire
/// server's `peer_alive`: one request owns this socket, so no lock).
fn peer_alive_tcp(s: &TcpStream) -> bool {
    if s.set_nonblocking(true).is_err() {
        return false;
    }
    let mut byte = [0u8; 1];
    let r = s.peek(&mut byte);
    let restored = s.set_nonblocking(false).is_ok();
    restored
        && match r {
            Ok(_) => true,
            Err(e) => e.kind() == std::io::ErrorKind::WouldBlock,
        }
}

fn handle_conn(inner: &Arc<GwInner>, stream: TcpStream,
               peer: SocketAddr) {
    // the read timeout doubles as the idle keep-alive limit AND the
    // drain bound for idle connections (timeout → RecvError::Closed)
    let _ = stream.set_read_timeout(Some(inner.cfg.keep_alive));
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    loop {
        let req = match http::read_request(&mut reader) {
            Ok(r) => r,
            Err(http::RecvError::Closed) => return,
            Err(http::RecvError::Io(e)) => {
                inner.conn_errors.record(ConnErrorKind::Io);
                crate::log_warn!(
                    "gateway: connection error from {peer}: {e}");
                return;
            }
            Err(http::RecvError::TooLarge(what)) => {
                inner.conn_errors.record(ConnErrorKind::TooLarge);
                let status =
                    if what.contains("body") { 413 } else { 431 };
                let body = openai::error_json("invalid_request_error",
                                              what).to_string();
                let _ = http::write_response(&mut writer, status,
                                             "application/json", &[],
                                             body.as_bytes(), true);
                return;
            }
            Err(http::RecvError::Bad(what)) => {
                inner.conn_errors.record(ConnErrorKind::Protocol);
                let body = openai::error_json("invalid_request_error",
                                              what).to_string();
                let _ = http::write_response(&mut writer, 400,
                                             "application/json", &[],
                                             body.as_bytes(), true);
                return;
            }
        };
        let close_after = req.wants_close()
            || inner.stop.load(Ordering::Relaxed);
        inner.metrics.http_requests.fetch_add(1, Ordering::Relaxed);
        inner.metrics.active.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let r = dispatch(inner, &req, &mut writer, close_after);
        inner.metrics.record_route(route_label(&req.path),
                                   t0.elapsed().as_secs_f64());
        inner.metrics.active.fetch_sub(1, Ordering::Relaxed);
        match r {
            Ok(true) if !close_after
                && !inner.stop.load(Ordering::Relaxed) => continue,
            Ok(_) => return,
            Err(e) => {
                // response write failed: the peer is gone
                inner.conn_errors.record(ConnErrorKind::Io);
                crate::log_debug!(
                    "gateway: write to {peer} failed: {e}");
                return;
            }
        }
    }
}

/// Route one request. `Ok(true)` = the connection may keep serving;
/// `Ok(false)` = close (SSE responses and errors that poison framing).
fn dispatch(inner: &Arc<GwInner>, req: &http::Request,
            writer: &mut TcpStream, close_after: bool)
    -> std::io::Result<bool> {
    let draining = inner.stop.load(Ordering::Relaxed);
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let (status, body) = if draining {
                (503, "draining")
            } else {
                (200, "ok")
            };
            http::write_response(writer, status, "text/plain", &[],
                                 body.as_bytes(), close_after)?;
            Ok(true)
        }
        ("GET", "/metrics") => {
            let text = metrics_text(inner);
            http::write_response(writer, 200,
                                 "text/plain; version=0.0.4", &[],
                                 text.as_bytes(), close_after)?;
            Ok(true)
        }
        ("GET", "/v1/models") => {
            let body = openai::models_json(&inner.cfg.model).to_string();
            http::write_response(writer, 200, "application/json", &[],
                                 body.as_bytes(), close_after)?;
            Ok(true)
        }
        ("POST", "/v1/completions") => {
            completions(inner, req, writer, close_after, draining)
        }
        ("POST", "/admin/drain") => {
            inner.stop.store(true, Ordering::Relaxed);
            let body = Json::obj(vec![
                ("draining", Json::Bool(true)),
            ]).to_string();
            http::write_response(writer, 202, "application/json", &[],
                                 body.as_bytes(), true)?;
            Ok(false)
        }
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/models") => {
            method_not_allowed(writer, "GET", close_after)
        }
        (_, "/v1/completions") | (_, "/admin/drain") => {
            method_not_allowed(writer, "POST", close_after)
        }
        _ => {
            let body = openai::error_json(
                "invalid_request_error", "unknown route").to_string();
            http::write_response(writer, 404, "application/json", &[],
                                 body.as_bytes(), close_after)?;
            Ok(true)
        }
    }
}

fn method_not_allowed(writer: &mut TcpStream, allow: &str,
                      close_after: bool) -> std::io::Result<bool> {
    let body = openai::error_json("invalid_request_error",
                                  "method not allowed").to_string();
    http::write_response(writer, 405, "application/json",
                         &[("Allow", allow.to_string())],
                         body.as_bytes(), close_after)?;
    Ok(true)
}

fn error_response(writer: &mut TcpStream, status: u16, kind: &str,
                  msg: &str, close_after: bool) -> std::io::Result<bool> {
    let body = openai::error_json(kind, msg).to_string();
    http::write_response(writer, status, "application/json", &[],
                         body.as_bytes(), close_after)?;
    Ok(true)
}

fn completions(inner: &Arc<GwInner>, req: &http::Request,
               writer: &mut TcpStream, close_after: bool, draining: bool)
    -> std::io::Result<bool> {
    let body = match std::str::from_utf8(&req.body) {
        Ok(b) => b,
        Err(_) => return error_response(writer, 400,
                                        "invalid_request_error",
                                        "body is not valid utf-8",
                                        close_after),
    };
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return error_response(writer, 400,
                                        "invalid_request_error",
                                        &format!("bad json: {e}"),
                                        close_after),
    };
    let c = match openai::parse_completion(&j) {
        Ok(c) => c,
        Err(m) => return error_response(writer, 400,
                                        "invalid_request_error", &m,
                                        close_after),
    };
    if let Some(m) = &c.model {
        if m != &inner.cfg.model {
            return error_response(writer, 404, "invalid_request_error",
                                  &format!("model not found: {m}"),
                                  close_after);
        }
    }
    if draining {
        return error_response(writer, 503, "overloaded",
                              "server is draining", close_after);
    }
    // ---- admission control -------------------------------------------
    let queued = inner.router.queue_depth();
    if inner.shed.should_shed(queued) {
        inner.metrics.shed.fetch_add(1, Ordering::Relaxed);
        let ra = ShedPolicy::retry_after_s(queued,
                                           inner.router.total_slots(),
                                           inner.router.e2e_p50());
        let body = openai::error_json(
            "overloaded",
            "admission queue is full, retry later").to_string();
        http::write_response(writer, 429, "application/json",
                             &[("Retry-After", ra.to_string())],
                             body.as_bytes(), close_after)?;
        return Ok(true);
    }
    inner.metrics.requests.fetch_add(1, Ordering::Relaxed);
    let id = format!("cmpl-{}",
                     inner.next_id.fetch_add(1, Ordering::Relaxed));
    let created = SystemTime::now().duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs()).unwrap_or(0);
    let model = inner.cfg.model.clone();
    // the SAME tokenizer as the wire path: the prefix cache is keyed on
    // token ids, so identical prompts hit it from either frontend
    let prompt_ids = inner.tok.encode(&c.prompt);
    let prompt_len = prompt_ids.len();
    let params = c.params;
    let t0 = Instant::now();
    let stream = inner.router.generate(prompt_ids.clone(),
                                       params.clone());

    if !c.stream {
        // ---- blocking ------------------------------------------------
        // probe the socket every few tokens so a vanished client frees
        // its decode slot instead of pinning it to max_tokens
        let probe = writer.try_clone();
        let mut since_probe = 0usize;
        let out = pump_generate(stream, &inner.tok,
                                &params.stop_strings, t0, |ts, _| {
            since_probe += ts.len().max(1);
            if since_probe >= 16 {
                since_probe = 0;
                if let Ok(p) = &probe {
                    if !peer_alive_tcp(p) {
                        crate::bail!("client disconnected");
                    }
                }
            }
            Ok(())
        });
        if out.client_gone {
            return Ok(false); // pump already cancelled the engine side
        }
        if let Some(e) = out.error {
            let body = openai::error_json("server_error", &e)
                .to_string();
            http::write_response(writer, 500, "application/json", &[],
                                 body.as_bytes(), close_after)?;
            return Ok(true);
        }
        // usage counts generated tokens; echo mutates text/ids after
        let completion_tokens = out.tokens.len();
        let mut text = out.text;
        let mut tokens = out.tokens;
        if params.echo {
            text = format!("{}{}", c.prompt, text);
            let mut all = prompt_ids;
            all.extend(&tokens);
            tokens = all;
        }
        let body = openai::completion_json(
            &id, &model, created, &text, &tokens,
            openai::finish_reason(&out.reason), prompt_len,
            completion_tokens).to_string();
        http::write_response(writer, 200, "application/json", &[],
                             body.as_bytes(), close_after)?;
        return Ok(true);
    }

    // ---- streaming (SSE) ---------------------------------------------
    writer.write_all(sse::PREAMBLE.as_bytes())?;
    writer.flush()?;
    if params.echo {
        let chunk = openai::chunk_json(&id, &model, created, &c.prompt,
                                       &prompt_ids, None, None);
        writer.write_all(sse::event(&chunk.to_string()).as_bytes())?;
        writer.flush()?;
    }
    let out = {
        let w = &mut *writer;
        pump_generate(stream, &inner.tok, &params.stop_strings, t0,
                      |ts, text| {
            // one SSE chunk per engine delta — the same cadence as the
            // wire protocol's v2 delta frames; a failed write here is a
            // client disconnect and cancels the engine side, freeing
            // the slot mid-decode
            let chunk = openai::chunk_json(&id, &model, created, text,
                                           ts, None, None);
            w.write_all(sse::event(&chunk.to_string()).as_bytes())?;
            w.flush()?;
            Ok(())
        })
    };
    if out.client_gone {
        return Ok(false);
    }
    if let Some(e) = out.error {
        let chunk = openai::error_json("server_error", &e);
        let _ = writer.write_all(
            sse::event(&chunk.to_string()).as_bytes());
        let _ = writer.write_all(sse::DONE_FRAME.as_bytes());
        let _ = writer.flush();
        return Ok(false);
    }
    let usage = openai::usage_json(prompt_len, out.tokens.len());
    let last = openai::chunk_json(&id, &model, created, "", &[],
                                  Some(openai::finish_reason(&out.reason)),
                                  Some(usage));
    writer.write_all(sse::event(&last.to_string()).as_bytes())?;
    writer.write_all(sse::DONE_FRAME.as_bytes())?;
    writer.flush()?;
    Ok(false) // SSE bodies are EOF-delimited
}

fn metrics_text(inner: &GwInner) -> String {
    let mut p = prom::Prom::new();
    prom::pool_samples(&mut p, &inner.router);
    let m = &inner.metrics;
    p.sample("m2_gateway_requests_total",
             "completion requests admitted by the gateway", "counter",
             &[], m.requests.load(Ordering::Relaxed) as f64);
    p.sample("m2_gateway_shed_total",
             "completion requests shed with 429 by admission control",
             "counter", &[], m.shed.load(Ordering::Relaxed) as f64);
    p.sample("m2_gateway_http_requests_total",
             "HTTP requests dispatched, all routes", "counter", &[],
             m.http_requests.load(Ordering::Relaxed) as f64);
    p.sample("m2_gateway_active",
             "HTTP requests currently inside a handler", "gauge", &[],
             m.active.load(Ordering::Relaxed) as f64);
    p.sample("m2_gateway_draining",
             "1 while graceful drain is in progress", "gauge", &[],
             if inner.stop.load(Ordering::Relaxed) { 1.0 } else { 0.0 });
    p.sample("m2_gateway_replicas",
             "engine replicas behind the gateway", "gauge", &[],
             inner.router.n_replicas() as f64);
    for (route, h) in m.route_hist.lock().unwrap().iter() {
        p.histogram("m2_http_request_seconds",
                    "HTTP request handler latency by route (SSE \
                     completions span the whole stream)",
                    &[("route", route.to_string())],
                    &ROUTE_LATENCY_LE, h);
    }
    prom::conn_error_samples(&mut p, &inner.conn_errors);
    p.render()
}
