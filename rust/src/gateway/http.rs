//! Minimal HTTP/1.1 framing for the gateway (DESIGN.md §10).
//!
//! Hand-rolled on `std::io` per the crate's hermetic no-crate-deps rule:
//! request-line + headers + `Content-Length` body, keep-alive by
//! default, no chunked transfer coding (requests must carry a length;
//! streaming responses are SSE over `Connection: close`). Size caps
//! bound untrusted input before any allocation proportional to it.

use std::io::{BufRead, Read, Write};

/// Cap on one header line AND on the whole header block (431 on breach).
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Cap on a request body (413 on breach) — completion prompts are far
/// below this; anything larger is hostile or misaddressed traffic.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// One parsed request. Header names are lowercased at parse time.
pub struct Request {
    pub method: String,
    /// request target with any query string stripped
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
    }

    /// `Connection: close` requested (HTTP/1.1 defaults to keep-alive).
    pub fn wants_close(&self) -> bool {
        self.header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// Why a request could not be read. The gateway maps these onto status
/// codes and the shared `conn_errors_by_kind` breakdown.
pub enum RecvError {
    /// clean EOF, or the idle keep-alive read timed out: close quietly
    Closed,
    /// transport error mid-request
    Io(std::io::Error),
    /// a size cap was breached (→ 431 or 413, then close)
    TooLarge(&'static str),
    /// malformed request (→ 400, then close)
    Bad(&'static str),
}

fn map_io(e: std::io::Error) -> RecvError {
    use std::io::ErrorKind;
    match e.kind() {
        // the per-connection read timeout fires between requests on an
        // idle keep-alive connection — that is a quiet close, which is
        // also what bounds graceful drain on idle connections
        ErrorKind::WouldBlock | ErrorKind::TimedOut => RecvError::Closed,
        ErrorKind::InvalidData => RecvError::Bad("non-utf8 request head"),
        ErrorKind::UnexpectedEof => RecvError::Bad("truncated request"),
        _ => RecvError::Io(e),
    }
}

/// Read one newline-terminated line of at most `cap` bytes (CR stripped).
/// `Ok(None)` = clean EOF before any byte arrived.
fn read_line_capped<R: BufRead>(r: &mut R, cap: usize)
    -> Result<Option<String>, RecvError> {
    let mut line = String::new();
    let n = r.by_ref().take(cap as u64 + 1).read_line(&mut line)
        .map_err(map_io)?;
    if n == 0 {
        return Ok(None);
    }
    if !line.ends_with('\n') {
        // either the take() limit cut the line (too long) or the peer
        // closed mid-line (truncated)
        return Err(if n > cap {
            RecvError::TooLarge("header line over cap")
        } else {
            RecvError::Bad("truncated request")
        });
    }
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(Some(line))
}

/// Parse one request off the connection. Blocking; respects any read
/// timeout set on the underlying socket (mapped to [`RecvError::Closed`]
/// between requests).
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Request, RecvError> {
    let start = match read_line_capped(r, MAX_HEADER_BYTES)? {
        None => return Err(RecvError::Closed),
        Some(l) => l,
    };
    let mut parts = start.split_whitespace();
    let method = parts.next().filter(|m| !m.is_empty())
        .ok_or(RecvError::Bad("empty request line"))?
        .to_string();
    let target = parts.next()
        .ok_or(RecvError::Bad("missing request target"))?;
    let version = parts.next()
        .ok_or(RecvError::Bad("missing http version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(RecvError::Bad("unsupported http version"));
    }
    // the gateway routes on the path alone
    let path = target.split('?').next().unwrap_or(target).to_string();
    let mut headers = Vec::new();
    let mut total = start.len();
    loop {
        let line = read_line_capped(r, MAX_HEADER_BYTES)?
            .ok_or(RecvError::Bad("truncated request"))?;
        if line.is_empty() {
            break; // end of headers
        }
        total += line.len();
        if total > MAX_HEADER_BYTES {
            return Err(RecvError::TooLarge("header block over cap"));
        }
        let (name, value) = line.split_once(':')
            .ok_or(RecvError::Bad("malformed header line"))?;
        headers.push((name.trim().to_ascii_lowercase(),
                      value.trim().to_string()));
    }
    let len = match headers.iter()
        .find(|(n, _)| n.as_str() == "content-length") {
        Some((_, v)) => v.parse::<usize>()
            .map_err(|_| RecvError::Bad("bad content-length"))?,
        None => 0,
    };
    if len > MAX_BODY_BYTES {
        return Err(RecvError::TooLarge("body over cap"));
    }
    let mut body = vec![0u8; len];
    if len > 0 {
        r.read_exact(&mut body).map_err(|e| match e.kind() {
            std::io::ErrorKind::UnexpectedEof =>
                RecvError::Bad("truncated body"),
            _ => map_io(e),
        })?;
    }
    Ok(Request { method, path, headers, body })
}

/// Canonical reason phrase for the statuses the gateway emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one complete response (status + headers + `Content-Length`
/// body). `extra` rides between the standard headers; `close` selects
/// the `Connection` header.
pub fn write_response(w: &mut impl Write, status: u16, content_type: &str,
                      extra: &[(&str, String)], body: &[u8], close: bool)
    -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
        status, reason(status), content_type, body.len());
    for (k, v) in extra {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str(if close {
        "Connection: close\r\n\r\n"
    } else {
        "Connection: keep-alive\r\n\r\n"
    });
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// One-shot HTTP client for tests and benches: send `method path` with
/// `body`, `Connection: close`, read to EOF. Returns
/// `(status, lowercased headers, body)`.
pub fn http_roundtrip(addr: &std::net::SocketAddr, method: &str,
                      path: &str, body: &[u8])
    -> crate::util::error::Result<(u16, Vec<(String, String)>, Vec<u8>)> {
    let mut s = std::net::TcpStream::connect(addr)?;
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: gateway\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len());
    s.write_all(head.as_bytes())?;
    s.write_all(body)?;
    s.flush()?;
    let mut buf = Vec::new();
    s.read_to_end(&mut buf)?;
    parse_response(&buf)
}

/// Split a complete response buffer into (status, headers, body).
pub fn parse_response(buf: &[u8])
    -> crate::util::error::Result<(u16, Vec<(String, String)>, Vec<u8>)> {
    let pos = buf.windows(4).position(|w| w == b"\r\n\r\n")
        .ok_or_else(|| crate::anyhow!("no header terminator in \
                                       response"))?;
    let head = std::str::from_utf8(&buf[..pos])?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line.split_whitespace().nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| crate::anyhow!("bad status line: {status_line}"))?;
    let mut headers = Vec::new();
    for l in lines {
        if let Some((n, v)) = l.split_once(':') {
            headers.push((n.trim().to_ascii_lowercase(),
                          v.trim().to_string()));
        }
    }
    Ok((status, headers, buf[pos + 4..].to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_body_and_query() {
        let raw = b"POST /v1/completions?debug=1 HTTP/1.1\r\n\
                    Host: x\r\nContent-Length: 4\r\n\
                    Connection: close\r\n\r\nabcd";
        let mut r = Cursor::new(raw.to_vec());
        let req = match read_request(&mut r) {
            Ok(q) => q,
            Err(_) => panic!("parse failed"),
        };
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"abcd");
        assert!(req.wants_close());
    }

    #[test]
    fn eof_is_closed_and_truncations_are_bad() {
        let mut r = Cursor::new(Vec::<u8>::new());
        assert!(matches!(read_request(&mut r), Err(RecvError::Closed)));
        // request line without its newline = peer died mid-line
        let mut r = Cursor::new(b"GET /x HTTP/1.1".to_vec());
        assert!(matches!(read_request(&mut r), Err(RecvError::Bad(_))));
        // headers promise more body than arrives
        let mut r = Cursor::new(
            b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc".to_vec());
        assert!(matches!(read_request(&mut r), Err(RecvError::Bad(_))));
        let mut r = Cursor::new(b"GET /x FTP/9\r\n\r\n".to_vec());
        assert!(matches!(read_request(&mut r), Err(RecvError::Bad(_))));
    }

    #[test]
    fn size_caps_are_enforced() {
        let long = format!("GET /{} HTTP/1.1\r\n\r\n",
                           "a".repeat(MAX_HEADER_BYTES + 10));
        let mut r = Cursor::new(long.into_bytes());
        assert!(matches!(read_request(&mut r),
                         Err(RecvError::TooLarge(_))));
        let big_body = format!(
            "POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1);
        let mut r = Cursor::new(big_body.into_bytes());
        assert!(matches!(read_request(&mut r),
                         Err(RecvError::TooLarge(_))));
    }

    #[test]
    fn response_roundtrips_through_parser() {
        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json",
                       &[("Retry-After", "3".to_string())], b"{}", true)
            .unwrap();
        let (status, headers, body) = parse_response(&out).unwrap();
        assert_eq!(status, 429);
        assert_eq!(body, b"{}");
        let ra = headers.iter().find(|(n, _)| n == "retry-after");
        assert_eq!(ra.map(|(_, v)| v.as_str()), Some("3"));
        let conn = headers.iter().find(|(n, _)| n == "connection");
        assert_eq!(conn.map(|(_, v)| v.as_str()), Some("close"));
    }
}
