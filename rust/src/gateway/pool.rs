//! Replica pool construction: N engines behind one [`Router`], all
//! publishing into one shared [`InFlightGauge`].
//!
//! Each replica owns its backend, plan cache, batcher, and prefix cache
//! (SSM state never migrates — DESIGN.md §3). The shared gauge is what
//! lets the gateway's admission control, the wire `metrics` op, and
//! `/metrics` read one consistent in-flight number no matter which
//! frontend the traffic arrived on.

use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::{Engine, EngineConfig, InFlightGauge, Router};
use crate::runtime::manifest::WeightsDtype;
use crate::runtime::open_backend_replicas;
use crate::util::error::Result;

pub struct PoolConfig {
    pub model: String,
    /// backend selector: `auto` | `reference` | `xla`
    pub backend: String,
    pub artifacts: PathBuf,
    pub replicas: usize,
    pub batch_cap: usize,
    pub prefix_cache_bytes: usize,
    /// optional trained checkpoint (.mbt), loaded into every replica
    pub checkpoint: Option<PathBuf>,
    /// weight stream precision pinned across the pool. `None` keeps
    /// whatever `M2_WEIGHTS` (normally written by
    /// `RuntimeOptions::export_env`) already says; `Some` overrides it
    /// before the replicas open, so every replica streams the same
    /// dtype — mixed pools would report inconsistent
    /// `bytes_streamed_per_token` and tokens/s.
    pub weights: Option<WeightsDtype>,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            model: "sim-130m".into(),
            backend: "auto".into(),
            artifacts: crate::artifacts_dir(),
            replicas: 1,
            batch_cap: 4,
            prefix_cache_bytes: 16 << 20,
            checkpoint: None,
            weights: None,
        }
    }
}

/// Open the backends, start the engines, and wire them under a router
/// that reads the shared gauge. Returns the router plus the gauge (the
/// gateway also hands the gauge to anything else that needs the
/// process-wide in-flight number).
pub fn build(cfg: PoolConfig) -> Result<(Arc<Router>, Arc<InFlightGauge>)> {
    let gauge = Arc::new(InFlightGauge::new());
    if let Some(w) = cfg.weights {
        // backends read the env at open time (the established knob
        // transport — see `runtime::options`), so writing it here pins
        // the whole pool to one stream dtype
        std::env::set_var("M2_WEIGHTS", w.as_str());
    }
    let backends = open_backend_replicas(&cfg.model, &cfg.backend,
                                         &cfg.artifacts, cfg.replicas)?;
    let mut replicas = Vec::with_capacity(cfg.replicas);
    for (i, mut backend) in backends.into_iter().enumerate() {
        if i == 0 {
            crate::log_info!(
                "pool: backend={} platform={} model={} ({:.1}M params, \
                 plan={}, weights={}, isa={})",
                backend.name(), backend.platform(), cfg.model,
                backend.cfg().n_params_total as f64 / 1e6,
                if backend.plan_stats().is_some() { "on" } else { "off" },
                backend.weights_dtype(), backend.isa());
        }
        if let Some(ckpt) = &cfg.checkpoint {
            let w = crate::tensor::load_mbt(ckpt)?;
            backend.load_weights(w)?;
            crate::log_info!("pool: replica {i} loaded checkpoint {}",
                             ckpt.display());
        }
        let ecfg = EngineConfig {
            batch_cap: cfg.batch_cap,
            prefix_cache_bytes: cfg.prefix_cache_bytes,
            in_flight_gauge: Some(Arc::clone(&gauge)),
            ..Default::default()
        };
        replicas.push(Arc::new(Engine::start(backend, ecfg)?));
    }
    crate::log_info!("pool: {} replica(s), batch_cap {}, prefix_cache \
                      {} B/replica",
                     cfg.replicas, cfg.batch_cap, cfg.prefix_cache_bytes);
    let router = Arc::new(Router::new(replicas)
                          .with_gauge(Arc::clone(&gauge)));
    Ok((router, gauge))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GenerateParams;

    #[test]
    fn pool_shares_one_gauge_across_replicas() {
        let (router, gauge) = build(PoolConfig {
            model: "tiny".into(),
            backend: "reference".into(),
            replicas: 2,
            batch_cap: 2,
            ..Default::default()
        }).unwrap();
        assert_eq!(router.n_replicas(), 2);
        assert_eq!(router.total_slots(), 4);
        assert_eq!(router.in_flight(), 0);
        // a completed request passes through the gauge and settles it
        let mut s = router.generate(
            vec![1, 2, 3], GenerateParams::new().max_new_tokens(2));
        let mut got = 0;
        while let Some(ev) = s.next_event() {
            if let crate::coordinator::Event::Tokens(t) = ev {
                got += t.len();
            }
        }
        assert!(got >= 1);
        assert_eq!(gauge.get(), 0, "settled request must free the gauge");
        assert_eq!(router.in_flight(), 0);
    }
}
