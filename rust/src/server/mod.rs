//! Line-delimited-JSON TCP server + client (DESIGN.md §3; the full wire
//! protocol table lives in README.md).
//!
//! Protocol (one JSON object per line, response on one line):
//!   → {"op":"generate","prompt":"text","max_new_tokens":32,
//!      "top_k":0,"seed":0}
//!   ← {"tokens":[..],"text":"...","n":32,"ms":12.3}           (final)
//!   → {"op":"metrics"}            ← {"replicas":[{..counters..}]}
//!   → {"op":"ping"}               ← {"ok":true}
//!   (anything else)               ← {"error":"..."} — the connection
//!                                    stays open after errors
//!
//! tokio is unavailable offline; the server runs a thread-pool accept loop
//! over std::net — adequate for the batch sizes this CPU target serves.
//! The server is backend-agnostic: it only sees the `Router` over engine
//! replicas, each driving any `runtime::Backend`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use crate::util::error::{Context, Result};

use crate::coordinator::{Router, Sampling};
use crate::eval::tokenizer::Tokenizer;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

pub struct Server {
    router: Arc<Router>,
    tokenizer: Arc<Tokenizer>,
}

impl Server {
    pub fn new(router: Arc<Router>, tokenizer: Arc<Tokenizer>) -> Server {
        Server { router, tokenizer }
    }

    /// Bind and serve until the process exits. Returns the bound address
    /// through the callback (port 0 supported for tests).
    pub fn serve(&self, addr: &str, threads: usize,
                 on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind {addr}"))?;
        on_bound(listener.local_addr()?);
        let pool = ThreadPool::new(threads);
        for stream in listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let router = Arc::clone(&self.router);
            let tok = Arc::clone(&self.tokenizer);
            pool.execute(move || {
                let _ = handle_conn(stream, router, tok);
            });
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, router: Arc<Router>,
               tok: Arc<Tokenizer>) -> Result<()> {
    let peer = stream.peer_addr().ok();
    crate::log_debug!("conn from {peer:?}");
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let req = match Json::parse(line.trim()) {
            Ok(j) => j,
            Err(e) => {
                write_json(&mut out, &Json::obj(vec![
                    ("error", Json::str(format!("bad json: {e}"))),
                ]))?;
                continue;
            }
        };
        match req.get("op").and_then(Json::as_str) {
            Some("ping") => {
                write_json(&mut out, &Json::obj(vec![
                    ("ok", Json::Bool(true)),
                ]))?;
            }
            Some("metrics") => {
                let mut reps = Vec::new();
                for i in 0..router.n_replicas() {
                    let s = router.replica(i).metrics.snapshot();
                    reps.push(Json::obj(vec![
                        ("completed", Json::num(s.completed as f64)),
                        ("tokens", Json::num(s.tokens_generated as f64)),
                        ("tok_per_s", Json::num(s.throughput_tps())),
                        ("ttft_p50_ms", Json::num(s.ttft_p50 * 1e3)),
                        ("e2e_p99_ms", Json::num(s.e2e_p99 * 1e3)),
                        ("occupancy", Json::num(s.mean_batch_occupancy)),
                    ]));
                }
                write_json(&mut out, &Json::obj(vec![
                    ("replicas", Json::Arr(reps)),
                ]))?;
            }
            Some("generate") => {
                let t0 = Instant::now();
                let prompt_text = req.get("prompt").and_then(Json::as_str)
                    .unwrap_or("");
                let n = req.get("max_new_tokens").and_then(Json::as_u64)
                    .unwrap_or(32) as usize;
                let k = req.get("top_k").and_then(Json::as_u64)
                    .unwrap_or(0) as usize;
                let seed = req.get("seed").and_then(Json::as_u64)
                    .unwrap_or(0);
                let prompt = tok.encode(prompt_text);
                let sampling = if k == 0 {
                    Sampling::Greedy
                } else {
                    Sampling::TopK { k, seed }
                };
                let stream = router.submit(prompt, n, sampling);
                match stream.collect() {
                    Ok(tokens) => {
                        let text = tok.decode(&tokens);
                        write_json(&mut out, &Json::obj(vec![
                            ("tokens", Json::Arr(tokens.iter()
                                .map(|&t| Json::num(t as f64)).collect())),
                            ("text", Json::str(text)),
                            ("n", Json::num(tokens.len() as f64)),
                            ("ms", Json::num(
                                t0.elapsed().as_secs_f64() * 1e3)),
                        ]))?;
                    }
                    Err(e) => {
                        write_json(&mut out, &Json::obj(vec![
                            ("error", Json::str(e)),
                        ]))?;
                    }
                }
            }
            _ => {
                write_json(&mut out, &Json::obj(vec![
                    ("error", Json::str("unknown op")),
                ]))?;
            }
        }
    }
}

fn write_json(w: &mut impl Write, j: &Json) -> Result<()> {
    writeln!(w, "{j}")?;
    w.flush()?;
    Ok(())
}

// ----------------------------------------------------------- client -----

/// Blocking client for the line-JSON protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect {addr}"))?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.writer, "{req}")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    pub fn generate(&mut self, prompt: &str, max_new_tokens: usize)
        -> Result<Json> {
        self.call(&Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str(prompt)),
            ("max_new_tokens", Json::num(max_new_tokens as f64)),
        ]))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.call(&Json::obj(vec![("op", Json::str("ping"))]))?;
        Ok(r.get("ok").and_then(Json::as_bool).unwrap_or(false))
    }
}
