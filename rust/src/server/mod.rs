//! Line-delimited-JSON TCP server + client (DESIGN.md §3; the full wire
//! protocol table lives in README.md).
//!
//! **v1** (unchanged, byte-compatible): a `generate` request using only
//! `prompt`/`max_new_tokens`/`top_k`/`seed` blocks and answers with one
//! `{"tokens":[..],"text":"...","n":N,"ms":12.3}` line.
//!
//! **v2** adds streaming and cancellation. `"stream":true` on `generate`
//! emits one delta frame per decode step plus a final usage frame, every
//! frame tagged with the request `id` so one connection can multiplex
//! several streams; `{"op":"cancel","id":N}` stops an in-flight stream
//! and frees its engine slot mid-decode (so does dropping the
//! connection). Requests may carry multiple `stop_tokens` and
//! `stop_strings` — stop strings are matched here, at the detokenising
//! layer, over the *byte* stream so a match split across a token
//! boundary still truncates the decoded text exactly; the engine side is
//! then cancelled to free the slot. `echo:true` prepends the prompt to
//! the response (an initial delta frame when streaming).
//!
//! tokio is unavailable offline; the server runs a thread-pool accept loop
//! over std::net — adequate for the batch sizes this CPU target serves.
//! Streaming requests hand their event pump to a dedicated thread so the
//! connection's read loop keeps accepting ops (that is what makes
//! `cancel` and stream multiplexing work). The server is
//! backend-agnostic: it only sees the `Router` over engine replicas, each
//! driving any `runtime::Backend`.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::error::{Context, Result};

use crate::coordinator::{CancelFn, ConnErrorKind, ConnErrors, Event,
                         FinishReason, GenerateParams, ResponseStream,
                         Router};
use crate::eval::tokenizer::Tokenizer;
use crate::runtime::SessionState;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

/// Server-side counters that live outside any engine replica.
#[derive(Default)]
pub struct ServerMetrics {
    /// connections that ended with an I/O or protocol-layer error
    /// (surfaced as `conn_errors` by the `metrics` op, with the per-kind
    /// breakdown under `conn_errors_by_kind`); shareable with other
    /// frontends via [`Server::with_conn_errors`]
    pub conn_errors: Arc<ConnErrors>,
}

pub struct Server {
    router: Arc<Router>,
    tokenizer: Arc<Tokenizer>,
    metrics: Arc<ServerMetrics>,
}

/// Per-connection table: wire-protocol request id → engine cancel hook.
type InflightMap = Arc<Mutex<HashMap<u64, CancelFn>>>;

impl Server {
    pub fn new(router: Arc<Router>, tokenizer: Arc<Tokenizer>) -> Server {
        Server { router, tokenizer,
                 metrics: Arc::new(ServerMetrics::default()) }
    }

    pub fn metrics(&self) -> Arc<ServerMetrics> {
        Arc::clone(&self.metrics)
    }

    /// Share a process-wide connection-error breakdown with other
    /// frontends (the HTTP gateway), so the wire `metrics` op and the
    /// gateway's `/metrics` report one combined count.
    pub fn with_conn_errors(mut self, conn_errors: Arc<ConnErrors>)
        -> Server {
        self.metrics = Arc::new(ServerMetrics { conn_errors });
        self
    }

    /// Bind and serve until the process exits. Returns the bound address
    /// through the callback (port 0 supported for tests).
    pub fn serve(&self, addr: &str, threads: usize,
                 on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let router = Arc::clone(&self.router);
        let tok = Arc::clone(&self.tokenizer);
        let sm = Arc::clone(&self.metrics);
        serve_listener(addr, threads, None, on_bound,
                       move |stream, peer| {
            if let Err(e) = handle_conn(stream, Arc::clone(&router),
                                        Arc::clone(&tok),
                                        Arc::clone(&sm)) {
                crate::log_warn!("connection error from {peer}: {e}");
                sm.conn_errors.record(ConnErrorKind::Io);
            }
        })
    }
}

/// Shared accept-loop plumbing for both frontends (wire server and HTTP
/// gateway): bind, report the bound address, and run `handler` on a
/// `ThreadPool` of `threads` workers, passing each connection its peer
/// address. With `stop = None` the loop accepts forever (the wire
/// server's process-lifetime mode). With `Some(flag)` the listener runs
/// non-blocking and the call RETURNS once the flag is set — and because
/// the pool's `Drop` joins every in-flight handler first, returning from
/// here is drain quiescence: no connection is still being served.
pub fn serve_listener(
    addr: &str, threads: usize, stop: Option<Arc<AtomicBool>>,
    on_bound: impl FnOnce(std::net::SocketAddr),
    handler: impl Fn(TcpStream, std::net::SocketAddr)
        + Send + Sync + 'static,
) -> Result<()> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("bind {addr}"))?;
    on_bound(listener.local_addr()?);
    let pool = ThreadPool::new(threads);
    let handler = Arc::new(handler);
    match stop {
        None => {
            for stream in listener.incoming() {
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                let peer = match stream.peer_addr() {
                    Ok(p) => p,
                    Err(_) => continue,
                };
                let h = Arc::clone(&handler);
                pool.execute(move || h(stream, peer));
            }
        }
        Some(stop) => {
            listener.set_nonblocking(true)?;
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, peer)) => {
                        // accepted sockets can inherit the listener's
                        // non-blocking mode on some platforms; handlers
                        // expect blocking reads (+ their own timeouts)
                        if stream.set_nonblocking(false).is_err() {
                            continue;
                        }
                        let h = Arc::clone(&handler);
                        pool.execute(move || h(stream, peer));
                    }
                    Err(e) if e.kind()
                        == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => {}
                }
            }
        }
    }
    // dropping the pool joins all in-flight handlers (drain)
    drop(pool);
    Ok(())
}

fn handle_conn(stream: TcpStream, router: Arc<Router>,
               tok: Arc<Tokenizer>, smetrics: Arc<ServerMetrics>)
    -> Result<()> {
    let peer = stream.peer_addr().ok();
    crate::log_debug!("conn from {peer:?}");
    let reader = BufReader::new(stream.try_clone()?);
    let writer = Arc::new(Mutex::new(stream));
    let inflight: InflightMap = Arc::new(Mutex::new(HashMap::new()));
    let result = conn_loop(reader, &writer, &router, &tok, &smetrics,
                           &inflight);
    // client disconnect (clean EOF or error): cancel every stream still
    // in flight on this connection so the engine slots free immediately
    let leftover: Vec<CancelFn> = inflight.lock().unwrap()
        .drain().map(|(_, c)| c).collect();
    for c in leftover {
        c(FinishReason::Cancelled);
    }
    result
}

fn conn_loop(mut reader: BufReader<TcpStream>,
             writer: &Arc<Mutex<TcpStream>>, router: &Arc<Router>,
             tok: &Arc<Tokenizer>, smetrics: &Arc<ServerMetrics>,
             inflight: &InflightMap) -> Result<()> {
    let mut line = String::new();
    let mut next_auto_id: u64 = 1;
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let req = match Json::parse(line.trim()) {
            Ok(j) => j,
            Err(e) => {
                write_frame(writer, &Json::obj(vec![
                    ("error", Json::str(format!("bad json: {e}"))),
                ]))?;
                continue;
            }
        };
        match req.get("op").and_then(Json::as_str) {
            Some("ping") => {
                write_frame(writer, &Json::obj(vec![
                    ("ok", Json::Bool(true)),
                ]))?;
            }
            Some("metrics") => {
                let mut reps = Vec::new();
                for i in 0..router.n_replicas() {
                    let s = router.replica(i).metrics.snapshot();
                    reps.push(Json::obj(vec![
                        ("completed", Json::num(s.completed as f64)),
                        ("cancelled", Json::num(s.cancelled as f64)),
                        ("queue_depth", Json::num(s.queue_depth as f64)),
                        ("in_flight", Json::num(s.in_flight as f64)),
                        ("tokens", Json::num(s.tokens_generated as f64)),
                        ("tok_per_s", Json::num(s.throughput_tps())),
                        ("ttft_p50_ms", Json::num(s.ttft_p50 * 1e3)),
                        ("e2e_p99_ms", Json::num(s.e2e_p99 * 1e3)),
                        ("occupancy", Json::num(s.mean_batch_occupancy)),
                        ("prefix_cache", Json::obj(vec![
                            ("hits", Json::num(s.prefix_hits as f64)),
                            ("misses", Json::num(s.prefix_misses as f64)),
                            ("evictions",
                             Json::num(s.prefix_evictions as f64)),
                            ("insertions",
                             Json::num(s.prefix_insertions as f64)),
                            ("bytes", Json::num(s.prefix_bytes as f64)),
                            ("entries",
                             Json::num(s.prefix_entries as f64)),
                        ])),
                    ]));
                }
                let by_kind: Vec<(&str, Json)> = ConnErrorKind::ALL
                    .iter()
                    .map(|&k| (k.as_str(), Json::num(
                        smetrics.conn_errors.get(k) as f64)))
                    .collect();
                write_frame(writer, &Json::obj(vec![
                    ("replicas", Json::Arr(reps)),
                    ("conn_errors", Json::num(
                        smetrics.conn_errors.total() as f64)),
                    ("conn_errors_by_kind", Json::obj(by_kind)),
                    ("in_flight_total",
                     Json::num(router.in_flight() as f64)),
                ]))?;
            }
            Some("cancel") => match req.get("id").and_then(Json::as_u64) {
                None => {
                    write_frame(writer, &Json::obj(vec![
                        ("error", Json::str("cancel requires a numeric \
                                             id")),
                    ]))?;
                }
                Some(id) => {
                    let hook = inflight.lock().unwrap().get(&id).cloned();
                    match hook {
                        Some(c) => {
                            // no ack frame: the stream's terminal
                            // "cancelled" frame IS the acknowledgment.
                            // (An in-band ack could race the terminal
                            // frame and desync later blocking reads.)
                            c(FinishReason::Cancelled);
                        }
                        None => {
                            // structured error: the op failed but the
                            // connection (and other streams) live on
                            write_frame(writer, &Json::obj(vec![
                                ("id", Json::num(id as f64)),
                                ("error", Json::str("unknown or finished \
                                                     id")),
                            ]))?;
                        }
                    }
                }
            },
            Some("generate") => {
                let r2 = Arc::clone(router);
                op_generate(&req, writer, router, tok, inflight,
                            &mut next_auto_id,
                            Box::new(move |p, params| {
                                r2.generate(p, params)
                            }))?;
            }
            Some("session_save") => {
                op_session_save(&req, writer, router, tok)?;
            }
            Some("session_resume") => {
                op_session_resume(&req, writer, router, tok, inflight,
                                  &mut next_auto_id)?;
            }
            _ => {
                write_frame(writer, &Json::obj(vec![
                    ("error", Json::str("unknown op")),
                ]))?;
            }
        }
    }
}

/// Cap on concurrently streaming requests per connection (each owns a
/// pump thread while queued or decoding).
const MAX_STREAMS_PER_CONN: usize = 32;

/// Fields whose presence marks a request as protocol v2 (their absence
/// keeps the non-streaming response byte-compatible with v1).
const V2_KEYS: &[&str] = &["id", "stream", "top_p", "temperature",
                           "stop_token", "stop_tokens", "stop_strings",
                           "echo"];

fn is_v2(req: &Json) -> bool {
    V2_KEYS.iter().any(|k| req.get(k).is_some())
}

/// Decode the wire fields of a `generate` request into [`GenerateParams`].
fn parse_params(req: &Json) -> GenerateParams {
    let mut p = GenerateParams::new()
        .max_new_tokens(req.get("max_new_tokens").and_then(Json::as_u64)
                        .unwrap_or(32) as usize)
        .top_k(req.get("top_k").and_then(Json::as_u64)
               .unwrap_or(0) as usize)
        .seed(req.get("seed").and_then(Json::as_u64).unwrap_or(0));
    if let Some(tp) = req.get("top_p").and_then(Json::as_f64) {
        p = p.top_p(tp as f32);
    }
    if let Some(t) = req.get("temperature").and_then(Json::as_f64) {
        p = p.temperature(t as f32);
    }
    if let Some(t) = req.get("stop_token").and_then(Json::as_i64) {
        p = p.stop_token(t as i32);
    }
    if let Some(a) = req.get("stop_tokens").and_then(Json::as_arr) {
        for v in a {
            if let Some(t) = v.as_i64() {
                p = p.stop_token(t as i32);
            }
        }
    }
    if let Some(a) = req.get("stop_strings").and_then(Json::as_arr) {
        for v in a {
            if let Some(s) = v.as_str() {
                p = p.stop_string(s);
            }
        }
    }
    if req.get("echo").and_then(Json::as_bool).unwrap_or(false) {
        p = p.echo(true);
    }
    p
}

/// Lower-half of `generate` and `session_resume`: parse params, spawn
/// the response stream via `spawn` (the only line the two ops differ
/// in), then drive the blocking or streaming reply path. `spawn` runs
/// exactly once.
fn op_generate(req: &Json, writer: &Arc<Mutex<TcpStream>>,
               _router: &Arc<Router>, tok: &Arc<Tokenizer>,
               inflight: &InflightMap, next_auto_id: &mut u64,
               spawn: Box<dyn FnOnce(Vec<i32>, GenerateParams)
                          -> ResponseStream>)
    -> Result<()> {
    let t0 = Instant::now();
    let prompt_text = req.get("prompt").and_then(Json::as_str)
        .unwrap_or("").to_string();
    let params = parse_params(req);
    let v2 = is_v2(req);
    let streaming = req.get("stream").and_then(Json::as_bool)
        .unwrap_or(false);
    let prompt = tok.encode(&prompt_text);
    let prompt_len = prompt.len();

    if !streaming {
        // ------------------------------------- blocking (v1-shaped) ---
        // A blocking client that disconnects mid-generate would
        // otherwise pin its slot until max_new_tokens: probe the socket
        // every few tokens (peek under the write lock — non-destructive,
        // pipelined request bytes just mean "alive") and let the pump's
        // client-gone path cancel the engine side.
        let probe_writer = Arc::clone(writer);
        let mut since_probe = 0usize;
        let stream = spawn(prompt.clone(), params.clone());
        let out = pump_generate(stream, tok, &params.stop_strings, t0,
                                |ts, _| {
            since_probe += ts.len().max(1);
            if since_probe >= 16 {
                since_probe = 0;
                if !peer_alive(&probe_writer) {
                    crate::bail!("client disconnected");
                }
            }
            Ok(())
        });
        if out.client_gone {
            return Ok(()); // nothing left to answer; read loop sees EOF
        }
        if let Some(e) = out.error {
            let mut fields = vec![("error", Json::str(e))];
            if let Some(id) = req.get("id").and_then(Json::as_u64) {
                fields.push(("id", Json::num(id as f64)));
            }
            return write_frame(writer, &Json::obj(fields));
        }
        let mut tokens = out.tokens;
        let mut text = out.text;
        let n = tokens.len();
        if params.echo {
            text = format!("{prompt_text}{text}");
            let mut all = prompt.clone();
            all.extend(&tokens);
            tokens = all;
        }
        let e2e_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut fields = vec![
            ("tokens", Json::Arr(tokens.iter()
                .map(|&t| Json::num(t as f64)).collect())),
            ("text", Json::str(text)),
            ("n", Json::num(n as f64)),
            ("ms", Json::num(e2e_ms)),
        ];
        if v2 {
            if let Some(id) = req.get("id").and_then(Json::as_u64) {
                fields.push(("id", Json::num(id as f64)));
            }
            fields.push(("finish_reason", Json::str(out.reason.as_str())));
            fields.push(("usage",
                         usage_json(prompt_len, n, out.ttft_ms, e2e_ms)));
        }
        return write_frame(writer, &Json::obj(fields));
    }

    // --------------------------------------------------- streaming ---
    let wire_id = match req.get("id").and_then(Json::as_u64) {
        Some(id) => id,
        None => {
            let g = inflight.lock().unwrap();
            while g.contains_key(next_auto_id) {
                *next_auto_id += 1;
            }
            let id = *next_auto_id;
            *next_auto_id += 1;
            id
        }
    };
    {
        let g = inflight.lock().unwrap();
        if g.contains_key(&wire_id) {
            // terminal frame (done:true) so stream readers don't hang
            return write_frame(writer, &Json::obj(vec![
                ("id", Json::num(wire_id as f64)),
                ("done", Json::Bool(true)),
                ("error", Json::str("id already in flight on this \
                                     connection")),
            ]));
        }
        // each streaming request owns a pump thread for its whole
        // queued+decode lifetime: bound them per connection so one
        // client pipelining thousands of streams can't spawn threads
        // without limit
        if g.len() >= MAX_STREAMS_PER_CONN {
            return write_frame(writer, &Json::obj(vec![
                ("id", Json::num(wire_id as f64)),
                ("done", Json::Bool(true)),
                ("error", Json::str("too many concurrent streams on \
                                     this connection")),
            ]));
        }
    }
    let stream = spawn(prompt, params.clone());
    if let Some(c) = stream.cancel_fn() {
        inflight.lock().unwrap().insert(wire_id, c);
    }
    // the pump owns the stream on its own thread so this connection's
    // read loop keeps accepting ops (cancel, more generates, ...)
    let writer2 = Arc::clone(writer);
    let tok2 = Arc::clone(tok);
    let inflight2 = Arc::clone(inflight);
    let echo_text = if params.echo { Some(prompt_text) } else { None };
    let stop_strings = params.stop_strings.clone();
    std::thread::Builder::new()
        .name("stream-pump".into())
        .spawn(move || {
            if let Some(p) = &echo_text {
                // echo rides an initial delta frame
                let _ = write_frame(&writer2, &delta_frame(wire_id, &[], p));
            }
            let out = pump_generate(stream, &tok2, &stop_strings, t0,
                                    |ts, text| {
                write_frame(&writer2, &delta_frame(wire_id, ts, text))
            });
            if out.client_gone {
                inflight2.lock().unwrap().remove(&wire_id);
                return; // connection dead: nothing left to write
            }
            let e2e_ms = t0.elapsed().as_secs_f64() * 1e3;
            let frame = if let Some(e) = out.error {
                Json::obj(vec![
                    ("id", Json::num(wire_id as f64)),
                    ("done", Json::Bool(true)),
                    ("error", Json::str(e)),
                ])
            } else {
                Json::obj(vec![
                    ("id", Json::num(wire_id as f64)),
                    ("done", Json::Bool(true)),
                    ("finish_reason", Json::str(out.reason.as_str())),
                    ("usage", usage_json(prompt_len, out.tokens.len(),
                                         out.ttft_ms, e2e_ms)),
                ])
            };
            // terminal frame BEFORE unregistering: a client that saw the
            // frame and reuses the id must not race our old map entry
            let _ = write_frame(&writer2, &frame);
            inflight2.lock().unwrap().remove(&wire_id);
        })?;
    Ok(())
}

/// `{"op":"session_save","prompt":"..."}` → prefill the prompt on the
/// least-loaded replica and reply with the frozen state as a hex blob:
/// `{"session":"<hex>","position":N,"n_bytes":M,"config":"..."}`. The
/// blob is self-describing (versioned, checksummed) and resumes on any
/// server running the same model config — see `session_resume`.
fn op_session_save(req: &Json, writer: &Arc<Mutex<TcpStream>>,
                   router: &Arc<Router>, tok: &Arc<Tokenizer>)
    -> Result<()> {
    let prompt_text = req.get("prompt").and_then(Json::as_str)
        .unwrap_or("");
    let prompt = tok.encode(prompt_text);
    match router.session_save(prompt) {
        Ok(state) => {
            let bytes = state.to_bytes();
            write_frame(writer, &Json::obj(vec![
                ("session", Json::str(hex_encode(&bytes))),
                ("position", Json::num(state.position as f64)),
                ("n_bytes", Json::num(bytes.len() as f64)),
                ("config", Json::str(state.config)),
            ]))
        }
        Err(e) => write_frame(writer, &Json::obj(vec![
            ("error", Json::str(format!("session_save: {e}"))),
        ])),
    }
}

/// `{"op":"session_resume","session":"<hex>", ...}` — everything else
/// (`prompt` = the optional continuation text, `stream`, sampling
/// fields, stop conditions) means exactly what it means on `generate`.
/// A malformed blob (bad hex, truncated, bit-flipped, wrong version or
/// config) answers with a structured `{"error":...}` frame; the
/// connection — and any concurrent streams on it — live on.
fn op_session_resume(req: &Json, writer: &Arc<Mutex<TcpStream>>,
                     router: &Arc<Router>, tok: &Arc<Tokenizer>,
                     inflight: &InflightMap, next_auto_id: &mut u64)
    -> Result<()> {
    let blob = match req.get("session").and_then(Json::as_str) {
        Some(s) => s,
        None => {
            return write_frame(writer, &Json::obj(vec![
                ("error", Json::str("session_resume requires a \
                                     \"session\" hex blob")),
            ]));
        }
    };
    let state = match hex_decode(blob)
        .and_then(|b| SessionState::from_bytes(&b)) {
        Ok(s) => s,
        Err(e) => {
            return write_frame(writer, &Json::obj(vec![
                ("error", Json::str(format!("bad session blob: {e}"))),
            ]));
        }
    };
    let r2 = Arc::clone(router);
    op_generate(req, writer, router, tok, inflight, next_auto_id,
                Box::new(move |p, params| {
                    r2.session_resume(state, p, params)
                }))
}

fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

fn hex_decode(s: &str) -> Result<Vec<u8>> {
    let s = s.as_bytes();
    if s.len() % 2 != 0 {
        crate::bail!("hex blob has odd length {}", s.len());
    }
    let nib = |c: u8| -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => crate::bail!("invalid hex byte {c:#04x}"),
        }
    };
    let mut out = Vec::with_capacity(s.len() / 2);
    for p in s.chunks_exact(2) {
        out.push((nib(p[0])? << 4) | nib(p[1])?);
    }
    Ok(out)
}

/// Result of pumping one generation stream to completion.
pub(crate) struct GenOutcome {
    /// generated tokens, truncated at a stop-string match
    pub(crate) tokens: Vec<i32>,
    /// decoded text, truncated at a stop-string match
    pub(crate) text: String,
    pub(crate) reason: FinishReason,
    pub(crate) ttft_ms: f64,
    pub(crate) error: Option<String>,
    /// the delta callback failed (client disconnected mid-stream)
    pub(crate) client_gone: bool,
}

/// Drive a [`ResponseStream`] to its terminal event, decoding tokens,
/// scanning for stop strings over the byte stream, and calling
/// `on_delta(tokens, text)` once per engine event. Text AND token ids
/// are held back in lockstep until they can no longer complete a stop
/// match, so emitted deltas never contain any part of a stop string and
/// the streamed token ids always agree with the final (truncated)
/// result and `usage.completion_tokens`. On a match the engine side is
/// stopped (freeing the batch slot) and the result truncated. A failing
/// `on_delta` is treated as a client disconnect → cancel.
pub(crate) fn pump_generate(
    mut stream: ResponseStream, tok: &Tokenizer, stop_strings: &[String],
    t0: Instant, mut on_delta: impl FnMut(&[i32], &str) -> Result<()>)
    -> GenOutcome {
    let mut scan = StopScan::new(stop_strings);
    let mut tokens: Vec<i32> = Vec::new();
    // cumulative decoded-byte end offset of each token (for truncation)
    let mut tok_ends: Vec<usize> = Vec::new();
    // tokens whose bytes are still held back, with their end offsets
    let mut pending: std::collections::VecDeque<(i32, usize)> =
        std::collections::VecDeque::new();
    let mut ttft_ms = 0.0;
    let mut reason = FinishReason::Length;
    let mut error = None;
    let mut client_gone = false;
    loop {
        match stream.next_event() {
            Some(Event::Tokens(ts)) => {
                if tokens.is_empty() && !ts.is_empty() {
                    ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
                }
                for &t in &ts {
                    scan.push(&tok.decode_bytes(&[t]));
                    tokens.push(t);
                    tok_ends.push(scan.total_len());
                    pending.push_back((t, scan.total_len()));
                }
                if scan.matched() {
                    // stop string completed: free the engine slot now —
                    // as a *completed* request, not a cancelled one
                    stream.cancel_as(FinishReason::StopString);
                    reason = FinishReason::StopString;
                    drain(&mut stream);
                    break;
                }
                let emit = scan.take_emittable();
                let ready = drain_ready(&mut pending, scan.emitted());
                if (!ready.is_empty() || !emit.is_empty())
                    && on_delta(&ready, &emit).is_err() {
                    stream.cancel();
                    reason = FinishReason::Cancelled;
                    client_gone = true;
                    drain(&mut stream);
                    break;
                }
            }
            Some(Event::Done { reason: r, .. }) => {
                reason = r;
                break;
            }
            Some(Event::Error(e)) => {
                error = Some(e);
                break;
            }
            None => break,
        }
    }
    if let Some(m) = scan.match_at() {
        // keep only tokens whose decoded bytes lie entirely before the
        // match — the wire result never leaks past the stop string
        let keep = tok_ends.iter().filter(|&&e| e <= m).count();
        tokens.truncate(keep);
    }
    if !client_gone && error.is_none() {
        // flush what is still held back (partial stop-string prefixes,
        // or the run-up to the match itself), tokens and text together
        let tail = scan.take_tail();
        let ready = drain_ready(&mut pending, scan.emitted());
        if !tail.is_empty() || !ready.is_empty() {
            let _ = on_delta(&ready, &tail);
        }
    }
    GenOutcome { text: scan.final_text(), tokens, reason, ttft_ms, error,
                 client_gone }
}

/// Pop the held-back tokens whose decoded bytes now lie entirely within
/// the emitted prefix (`end offset <= upto`).
fn drain_ready(pending: &mut std::collections::VecDeque<(i32, usize)>,
               upto: usize) -> Vec<i32> {
    let mut out = Vec::new();
    while pending.front().is_some_and(|&(_, e)| e <= upto) {
        out.push(pending.pop_front().unwrap().0);
    }
    out
}

/// Consume buffered events until the engine acknowledges the cancel
/// with its terminal event.
fn drain(stream: &mut ResponseStream) {
    while let Some(ev) = stream.next_event() {
        if matches!(ev, Event::Done { .. } | Event::Error(_)) {
            break;
        }
    }
}

/// Incremental stop-string scanner over the decoded **byte** stream, so
/// a stop string split across a token boundary (or a multi-byte UTF-8
/// character) still matches and truncates exactly. Semantics: the first
/// stop string to *complete* in the stream wins (earliest match
/// position on ties within one push) — output already emitted cannot be
/// recalled to favour a longer match that completes later. Each push
/// searches only the window that can contain a new match, so long
/// streams stay O(n · pattern).
struct StopScan {
    pats: Vec<Vec<u8>>,
    buf: Vec<u8>,
    emitted: usize,
    match_at: Option<usize>,
}

impl StopScan {
    fn new(stop_strings: &[String]) -> StopScan {
        StopScan {
            pats: stop_strings.iter()
                .filter(|s| !s.is_empty())
                .map(|s| s.as_bytes().to_vec())
                .collect(),
            buf: Vec::new(),
            emitted: 0,
            match_at: None,
        }
    }

    fn push(&mut self, bytes: &[u8]) {
        if self.match_at.is_some() {
            return;
        }
        let old_len = self.buf.len();
        self.buf.extend_from_slice(bytes);
        let mut best: Option<usize> = None;
        for p in &self.pats {
            // every previous push scanned the buffer, so a new match
            // must involve at least one new byte: searching only the
            // window that can contain one keeps long streams O(n)
            let from = old_len.saturating_sub(p.len() - 1);
            if let Some(i) = find_sub(&self.buf[from..], p) {
                let i = i + from;
                best = Some(best.map_or(i, |b: usize| b.min(i)));
            }
        }
        self.match_at = best;
    }

    fn matched(&self) -> bool {
        self.match_at.is_some()
    }

    fn match_at(&self) -> Option<usize> {
        self.match_at
    }

    fn total_len(&self) -> usize {
        self.buf.len()
    }

    /// Byte offset up to which text has been released to the client.
    fn emitted(&self) -> usize {
        self.emitted
    }

    /// End of the text this request will ever deliver: the earliest
    /// stop-string match, else everything decoded so far.
    fn end(&self) -> usize {
        self.match_at.unwrap_or(self.buf.len())
    }

    /// Bytes that can no longer participate in a future stop match
    /// (everything except the longest buffer suffix that is a proper
    /// prefix of some stop string), floored to a UTF-8 boundary.
    fn take_emittable(&mut self) -> String {
        let mut hold = 0;
        for p in &self.pats {
            let maxl = (p.len() - 1).min(self.buf.len());
            for l in (1..=maxl).rev() {
                if self.buf.ends_with(&p[..l]) {
                    hold = hold.max(l);
                    break;
                }
            }
        }
        let safe = utf8_floor(&self.buf, self.buf.len() - hold);
        self.take_to(safe)
    }

    /// Everything not yet emitted, up to `end()`.
    fn take_tail(&mut self) -> String {
        self.take_to(self.end())
    }

    fn take_to(&mut self, to: usize) -> String {
        let to = to.max(self.emitted);
        let s = String::from_utf8_lossy(&self.buf[self.emitted..to])
            .into_owned();
        self.emitted = to;
        s
    }

    /// Full (stop-truncated) text of the request.
    fn final_text(&self) -> String {
        String::from_utf8_lossy(&self.buf[..self.end()]).into_owned()
    }
}

fn find_sub(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    hay.windows(needle.len()).position(|w| w == needle)
}

/// Largest `j <= i` that does not split a UTF-8 character of `b` —
/// including an incomplete multi-byte sequence still waiting for its
/// continuation bytes at the end of the buffer.
fn utf8_floor(b: &[u8], i: usize) -> usize {
    let i = i.min(b.len());
    if i == 0 {
        return 0;
    }
    // lead byte of the character containing position i-1
    let mut l = i - 1;
    while l > 0 && (b[l] & 0xC0) == 0x80 {
        l -= 1;
    }
    if (b[l] & 0xC0) == 0x80 {
        return 0; // nothing but continuation bytes: hold everything
    }
    if l + utf8_char_len(b[l]) <= i {
        i // the character is complete before the cut
    } else {
        l // the cut splits it: floor to its lead byte
    }
}

fn utf8_char_len(lead: u8) -> usize {
    match lead {
        0xF0..=0xF7 => 4,
        0xE0..=0xEF => 3,
        0xC0..=0xDF => 2,
        _ => 1,
    }
}

fn usage_json(prompt_tokens: usize, completion_tokens: usize,
              ttft_ms: f64, e2e_ms: f64) -> Json {
    Json::obj(vec![
        ("prompt_tokens", Json::num(prompt_tokens as f64)),
        ("completion_tokens", Json::num(completion_tokens as f64)),
        ("ttft_ms", Json::num(ttft_ms)),
        ("e2e_ms", Json::num(e2e_ms)),
    ])
}

fn delta_frame(id: u64, tokens: &[i32], text: &str) -> Json {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("delta", Json::obj(vec![
            ("tokens", Json::Arr(tokens.iter()
                .map(|&t| Json::num(t as f64)).collect())),
            ("text", Json::str(text)),
        ])),
    ])
}

fn write_frame(w: &Mutex<TcpStream>, j: &Json) -> Result<()> {
    // render outside the lock and write the whole line in one syscall:
    // Json's recursive Display would otherwise issue one write() per
    // fragment on the unbuffered socket, all while holding the writer
    // mutex that every pump and the read loop share
    let mut line = j.to_string();
    line.push('\n');
    let mut g = w.lock().unwrap();
    g.write_all(line.as_bytes())?;
    g.flush()?;
    Ok(())
}

/// Non-destructive liveness check: a one-byte non-blocking peek under
/// the write lock. `WouldBlock`, pipelined request bytes, and `Ok(0)`
/// (FIN — a half-closed write side, e.g. `printf ... | nc` scripting
/// clients that still read the response) all mean "keep serving"; only
/// a hard socket error (connection reset and friends) means the peer
/// is truly gone. Orderly disconnects of blocking requests are instead
/// noticed when the response write fails; streaming requests detect
/// every disconnect at the next delta write. Holding the write lock
/// keeps the non-blocking toggle from racing a concurrent streaming
/// pump's write.
pub(crate) fn peer_alive(w: &Mutex<TcpStream>) -> bool {
    let g = w.lock().unwrap();
    if g.set_nonblocking(true).is_err() {
        return false;
    }
    let mut byte = [0u8; 1];
    let r = g.peek(&mut byte);
    let restored = g.set_nonblocking(false).is_ok();
    restored
        && match r {
            Ok(_) => true,
            Err(e) => e.kind() == std::io::ErrorKind::WouldBlock,
        }
}

/// Build the wire-level `generate` request for [`GenerateParams`]
/// (shared by [`Client`] and external drivers).
pub fn generate_request_json(prompt: &str, p: &GenerateParams,
                             id: Option<u64>, stream: bool) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str(prompt)),
        ("max_new_tokens", Json::num(p.max_new_tokens as f64)),
    ];
    if p.top_k > 0 {
        fields.push(("top_k", Json::num(p.top_k as f64)));
    }
    if p.top_p < 1.0 {
        fields.push(("top_p", Json::num(p.top_p as f64)));
    }
    if p.temperature != 1.0 {
        fields.push(("temperature", Json::num(p.temperature as f64)));
    }
    if p.seed != 0 {
        fields.push(("seed", Json::num(p.seed as f64)));
    }
    if !p.stop_tokens.is_empty() {
        fields.push(("stop_tokens", Json::Arr(p.stop_tokens.iter()
            .map(|&t| Json::num(t as f64)).collect())));
    }
    if !p.stop_strings.is_empty() {
        fields.push(("stop_strings", Json::Arr(p.stop_strings.iter()
            .map(|s| Json::str(s.clone())).collect())));
    }
    if p.echo {
        fields.push(("echo", Json::Bool(true)));
    }
    if let Some(id) = id {
        fields.push(("id", Json::num(id as f64)));
    }
    if stream {
        fields.push(("stream", Json::Bool(true)));
    }
    Json::obj(fields)
}

// ----------------------------------------------------------- client -----

/// Blocking client for the line-JSON protocol (v1 + v2).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

/// One frame of a streaming `generate` as seen by [`Client::generate_stream`].
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// incremental tokens + safe-to-display text
    Delta { tokens: Vec<i32>, text: String },
    /// terminal usage frame
    Done { finish_reason: String, usage: Json },
    /// terminal error frame
    Error(String),
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect {addr}"))?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.writer, "{req}")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    /// v1 blocking generate (greedy, default fields only).
    pub fn generate(&mut self, prompt: &str, max_new_tokens: usize)
        -> Result<Json> {
        self.call(&Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str(prompt)),
            ("max_new_tokens", Json::num(max_new_tokens as f64)),
        ]))
    }

    /// v2 blocking generate with full [`GenerateParams`]; the response
    /// carries `id`, `finish_reason`, and `usage`.
    pub fn generate_with(&mut self, prompt: &str, params: &GenerateParams)
        -> Result<Json> {
        let id = self.next_id;
        self.next_id += 1;
        self.call(&generate_request_json(prompt, params, Some(id), false))
    }

    /// v2 streaming generate: returns an iterator of [`Frame`]s
    /// (deltas, then one terminal `Done`/`Error`). Call
    /// [`GenStream::cancel`] to stop it server-side mid-decode.
    pub fn generate_stream<'a>(&'a mut self, prompt: &str,
                               params: &GenerateParams)
        -> Result<GenStream<'a>> {
        let id = self.next_id;
        self.next_id += 1;
        let req = generate_request_json(prompt, params, Some(id), true);
        writeln!(self.writer, "{req}")?;
        self.writer.flush()?;
        Ok(GenStream { c: self, id, done: false })
    }

    /// Fire a cancel for request `id`. A found id produces no ack —
    /// the stream's terminal `"cancelled"` frame is the acknowledgment;
    /// an unknown/finished id produces an in-band structured error
    /// frame (which an active [`GenStream`] skips).
    pub fn cancel(&mut self, id: u64) -> Result<()> {
        writeln!(self.writer, "{}", Json::obj(vec![
            ("op", Json::str("cancel")),
            ("id", Json::num(id as f64)),
        ]))?;
        self.writer.flush()?;
        Ok(())
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.call(&Json::obj(vec![("op", Json::str("ping"))]))?;
        Ok(r.get("ok").and_then(Json::as_bool).unwrap_or(false))
    }

    /// Save the generation state after `prompt`; returns the server's
    /// `{"session":"<hex>","position":..,"n_bytes":..,"config":..}`
    /// frame (or its `{"error":..}` frame).
    pub fn session_save(&mut self, prompt: &str) -> Result<Json> {
        self.call(&Json::obj(vec![
            ("op", Json::str("session_save")),
            ("prompt", Json::str(prompt)),
        ]))
    }

    /// Blocking resume from a saved session blob. `prompt` is the
    /// optional continuation text; sampling fields ride on `params` as
    /// with [`Client::generate_with`].
    pub fn session_resume(&mut self, session_hex: &str, prompt: &str,
                          params: &GenerateParams) -> Result<Json> {
        let id = self.next_id;
        self.next_id += 1;
        let mut j = generate_request_json(prompt, params, Some(id), false);
        if let Json::Obj(ref mut m) = j {
            m.insert("op".into(), Json::str("session_resume"));
            m.insert("session".into(), Json::str(session_hex));
        }
        self.call(&j)
    }
}

/// Iterator over the frames of one streaming generate (single-stream
/// consumption; multiplexing clients should speak the wire protocol
/// directly and demux frames by `id`).
pub struct GenStream<'a> {
    c: &'a mut Client,
    pub id: u64,
    done: bool,
}

impl<'a> GenStream<'a> {
    /// Next frame for this request; `None` after the terminal frame.
    pub fn next_frame(&mut self) -> Result<Option<Frame>> {
        if self.done {
            return Ok(None);
        }
        let mut line = String::new();
        loop {
            line.clear();
            if self.c.reader.read_line(&mut line)? == 0 {
                self.done = true;
                return Ok(Some(Frame::Error(
                    "server closed connection".into())));
            }
            let j = Json::parse(line.trim())?;
            if let Some(d) = j.get("delta") {
                let tokens = d.get("tokens").and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_i64)
                         .map(|t| t as i32).collect())
                    .unwrap_or_default();
                let text = d.get("text").and_then(Json::as_str)
                    .unwrap_or("").to_string();
                return Ok(Some(Frame::Delta { tokens, text }));
            }
            if j.get("done").and_then(Json::as_bool).unwrap_or(false) {
                self.done = true;
                if let Some(e) = j.get("error").and_then(Json::as_str) {
                    return Ok(Some(Frame::Error(e.to_string())));
                }
                let finish_reason = j.get("finish_reason")
                    .and_then(Json::as_str).unwrap_or("").to_string();
                let usage = j.get("usage").cloned().unwrap_or(Json::Null);
                return Ok(Some(Frame::Done { finish_reason, usage }));
            }
            // anything else on the line (structured errors for other
            // ops, e.g. a cancel of an unknown id) is skipped by this
            // single-stream reader
        }
    }

    /// Cancel this stream server-side; frames already in flight still
    /// arrive, then the terminal frame reports `"cancelled"`.
    pub fn cancel(&mut self) -> Result<()> {
        let id = self.id;
        self.c.cancel(id)
    }
}

impl<'a> Iterator for GenStream<'a> {
    type Item = Result<Frame>;

    fn next(&mut self) -> Option<Result<Frame>> {
        match self.next_frame() {
            Ok(Some(f)) => Some(Ok(f)),
            Ok(None) => None,
            Err(e) => {
                self.done = true;
                Some(Err(e))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stop_scan_exact_and_cross_boundary() {
        let stops = vec!["END".to_string()];
        let mut s = StopScan::new(&stops);
        s.push(b"hello E");            // 'E' could start a match: held
        assert!(!s.matched());
        let first = s.take_emittable();
        assert_eq!(first, "hello ");   // the 'E' is held back
        s.push(b"ND trailing");        // completes across the boundary
        assert!(s.matched());
        assert_eq!(s.match_at(), Some(6));
        assert_eq!(s.final_text(), "hello ");
        // nothing between emitted and the match start remains
        assert_eq!(s.take_tail(), "");
    }

    #[test]
    fn stop_scan_earliest_of_multiple() {
        let stops = vec!["xyz".to_string(), "lo".to_string()];
        let mut s = StopScan::new(&stops);
        s.push(b"hello world");
        assert_eq!(s.match_at(), Some(3)); // "lo" at offset 3
        assert_eq!(s.final_text(), "hel");
    }

    #[test]
    fn stop_scan_no_match_flushes_everything() {
        let stops = vec!["ZZZ".to_string()];
        let mut s = StopScan::new(&stops);
        s.push(b"abc");
        s.push(b"def");
        let mut out = s.take_emittable();
        out.push_str(&s.take_tail());
        assert_eq!(out, "abcdef");
        assert_eq!(s.final_text(), "abcdef");
    }

    #[test]
    fn stop_scan_holds_partial_utf8() {
        // 'é' = 0xC3 0xA9 split across two pushes must not be emitted
        // as replacement characters
        let mut s = StopScan::new(&[]);
        s.push(&[0xC3]);
        assert_eq!(s.take_emittable(), "");
        s.push(&[0xA9]);
        let mut out = s.take_emittable();
        out.push_str(&s.take_tail());
        assert_eq!(out, "é");
    }

    #[test]
    fn utf8_floor_walks_to_boundary() {
        let b = "aé".as_bytes(); // [0x61, 0xC3, 0xA9]
        assert_eq!(utf8_floor(b, 3), 3);
        assert_eq!(utf8_floor(b, 2), 1); // inside 'é'
        assert_eq!(utf8_floor(b, 1), 1);
        assert_eq!(utf8_floor(b, 0), 0);
    }

    #[test]
    fn hex_round_trip_and_rejects() {
        let b: Vec<u8> = (0u16..=255).map(|x| x as u8).collect();
        assert_eq!(hex_decode(&hex_encode(&b)).unwrap(), b);
        assert_eq!(hex_encode(&[0x4d, 0x02]), "4d02");
        assert!(hex_decode("abc").is_err(), "odd length");
        assert!(hex_decode("zz").is_err(), "non-hex digit");
        assert_eq!(hex_decode("").unwrap(), Vec::<u8>::new());
        assert_eq!(hex_decode("FFfe").unwrap(), vec![0xff, 0xfe]);
    }

    #[test]
    fn v2_detection() {
        let v1 = Json::parse(
            r#"{"op":"generate","prompt":"x","max_new_tokens":4}"#)
            .unwrap();
        assert!(!is_v2(&v1));
        let v2 = Json::parse(
            r#"{"op":"generate","prompt":"x","stop_token":3}"#).unwrap();
        assert!(is_v2(&v2));
    }

    #[test]
    fn request_json_roundtrips_params() {
        let p = GenerateParams::new().max_new_tokens(9).top_k(4).seed(3)
            .stop_token(7).stop_string("ab").echo(true);
        let j = generate_request_json("hi", &p, Some(5), true);
        let back = parse_params(&j);
        assert_eq!(back.max_new_tokens, 9);
        assert_eq!(back.top_k, 4);
        assert_eq!(back.seed, 3);
        assert_eq!(back.stop_tokens, vec![7]);
        assert_eq!(back.stop_strings, vec!["ab".to_string()]);
        assert!(back.echo);
        assert_eq!(j.get("id").and_then(Json::as_u64), Some(5));
        assert_eq!(j.get("stream").and_then(Json::as_bool), Some(true));
        assert!(is_v2(&j));
    }
}
