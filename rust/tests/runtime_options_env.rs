//! RuntimeOptions env layering + export, isolated in its own test
//! binary.
//!
//! This file must contain exactly ONE test: `std::env::set_var` is not
//! thread-safe against the `env::var` reads other tests perform
//! (concurrent setenv/getenv is UB on glibc), and cargo runs all tests
//! of one binary in parallel threads. A single test in a dedicated
//! binary serialises by construction. The pure layering/validation
//! tests live in `runtime::options` itself.

use mamba2_serve::runtime::{Backend, CliOverrides, ReferenceBackend,
                            RuntimeOptions};
use mamba2_serve::tensor::kernels::Isa;

#[test]
fn env_layer_resolves_exports_and_reaches_backends() {
    for k in ["M2_PLAN", "M2_WEIGHTS", "M2_THREADS", "M2_ISA",
              "M2_FUSE"] {
        std::env::remove_var(k);
    }

    // clean env → pure defaults
    let o = RuntimeOptions::resolve(&CliOverrides::default()).unwrap();
    assert_eq!(o, RuntimeOptions::default());

    // env fills what the CLI leaves unset; CLI wins where both speak
    std::env::set_var("M2_ISA", "scalar");
    std::env::set_var("M2_THREADS", "3");
    std::env::set_var("M2_WEIGHTS", "bf16");
    let o = RuntimeOptions::resolve(&CliOverrides {
        weights: Some("f32"),
        ..Default::default()
    }).unwrap();
    assert_eq!(o.threads, Some(3), "env layer");
    assert_eq!(o.isa, Isa::Scalar, "env layer");
    assert_eq!(o.weights.as_str(), "f32", "cli beats env");

    // an inherited typo is loud, not silently the default
    std::env::set_var("M2_ISA", "avx512");
    let err = RuntimeOptions::resolve(&CliOverrides::default())
        .unwrap_err();
    assert!(err.contains("--isa / M2_ISA"), "{err:?}");
    // ...unless the CLI overrides it before it is ever read
    std::env::remove_var("M2_THREADS");
    let o = RuntimeOptions::resolve(&CliOverrides {
        isa: Some("auto"),
        ..Default::default()
    }).unwrap();
    assert_eq!(o.isa, Isa::detect(), "auto resolved to a host tier");

    // export_env writes the *resolved* options back, and a backend
    // opened afterwards (which reads the env at open time) sees them
    o.export_env();
    assert_eq!(std::env::var("M2_ISA").unwrap(),
               Isa::detect().label(), "auto exported concretely");
    assert_eq!(std::env::var("M2_WEIGHTS").unwrap(), "bf16");
    assert!(std::env::var("M2_THREADS").is_err(),
            "unset threads stays unset (backend auto-sizes)");
    let b = ReferenceBackend::seeded("tiny", 0).unwrap();
    assert_eq!(b.isa(), Isa::detect().label());
    assert_eq!(b.weights_dtype(), "bf16");

    // the fuse knob rides the same transport: resolved → exported →
    // read by the next backend open
    assert_eq!(std::env::var("M2_FUSE").unwrap(), "on",
               "default fuse mode exported explicitly");
    let o = RuntimeOptions::resolve(&CliOverrides {
        fuse: Some("off"),
        ..Default::default()
    }).unwrap();
    o.export_env();
    assert_eq!(std::env::var("M2_FUSE").unwrap(), "off");

    for k in ["M2_PLAN", "M2_WEIGHTS", "M2_THREADS", "M2_ISA",
              "M2_FUSE"] {
        std::env::remove_var(k);
    }
}
