//! Property-based suites over the coordinator substrates (propcheck).

use mamba2_serve::coordinator::batcher::{ActiveSeq, Admission, Batcher};
use mamba2_serve::coordinator::request::{GenRequest, GenerateParams};
use mamba2_serve::coordinator::slots::SlotPool;
use mamba2_serve::eval::Tokenizer;
use mamba2_serve::util::json::Json;
use mamba2_serve::util::prng::Rng;
use mamba2_serve::util::propcheck::{check, usize_in, vec_of, Config};

// ------------------------------------------------------------ slot pool ---

#[test]
fn prop_slot_pool_conservation() {
    // any interleaving of allocs/frees keeps used + free == capacity and
    // never double-assigns a slot
    let gen = vec_of(usize_in(0, 2), 200); // 0,1 = alloc; 2 = free-random
    check(&Config { cases: 300, ..Default::default() }, &gen, |ops| {
        let mut pool = SlotPool::new(8);
        let mut held = Vec::new();
        let mut rng = Rng::new(42);
        for &op in ops {
            if op < 2 {
                if let Some(s) = pool.alloc(op as u64) {
                    if held.contains(&s) {
                        return false; // double-assignment!
                    }
                    held.push(s);
                }
            } else if !held.is_empty() {
                let i = rng.below(held.len() as u64) as usize;
                pool.free(held.swap_remove(i));
            }
            if pool.used() + (pool.capacity() - pool.used()) != 8 {
                return false;
            }
            if pool.used() != held.len() {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_slot_pool_never_exceeds_capacity() {
    let gen = usize_in(1, 64);
    check(&Config::default(), &gen, |&cap| {
        let mut pool = SlotPool::new(cap);
        for i in 0..cap + 10 {
            pool.alloc(i as u64);
        }
        pool.used() == cap && pool.is_full()
    });
}

// -------------------------------------------------------------- batcher ---

fn mk_req(id: u64, n: usize) -> GenRequest {
    GenRequest { id, prompt: vec![1],
                 params: GenerateParams::new().max_new_tokens(n.max(1)) }
}

#[test]
fn prop_batcher_completes_all_requests() {
    // for any request-length mix, driving the batcher to idle generates
    // exactly max_new_tokens per request and never leaks a slot
    let gen = vec_of(usize_in(1, 9), 24);
    check(&Config { cases: 200, ..Default::default() }, &gen, |lens| {
        let mut b = Batcher::new(3);
        for (i, &n) in lens.iter().enumerate() {
            b.submit(mk_req(i as u64, n));
        }
        let mut produced = vec![0usize; lens.len()];
        let mut guard = 0;
        while !b.is_idle() {
            guard += 1;
            if guard > 10_000 {
                return false; // livelock
            }
            let mut admitted = 0;
            while let Admission::Admit(req, slot) = b.next_admission(admitted)
            {
                admitted += 1;
                // model "prefill produced first token"
                produced[req.id as usize] += 1;
                if req.params.max_new_tokens == 1 {
                    b.slots.free(slot);
                    continue;
                }
                b.activate(ActiveSeq {
                    req_id: req.id, slot, last_token: 0, generated: 1,
                    max_new_tokens: req.params.max_new_tokens,
                    sampling: req.params.sampling(), stop_tokens: vec![],
                });
            }
            let act: Vec<_> = b.active_seqs().iter()
                .map(|s| s.slot).collect();
            for slot in act {
                let id = b.slots.owner(slot).unwrap() as usize;
                produced[id] += 1;
                let _ = b.advance(slot, 5);
            }
        }
        produced.iter().zip(lens).all(|(&p, &n)| p == n.max(1))
            && b.slots.used() == 0
    });
}

#[test]
fn prop_batcher_active_never_exceeds_cap() {
    let gen = vec_of(usize_in(1, 5), 30);
    check(&Config { cases: 150, ..Default::default() }, &gen, |lens| {
        let cap = 4;
        let mut b = Batcher::new(cap);
        for (i, &n) in lens.iter().enumerate() {
            b.submit(mk_req(i as u64, n));
        }
        let mut guard = 0;
        while !b.is_idle() && guard < 10_000 {
            guard += 1;
            let mut admitted = 0;
            while let Admission::Admit(req, slot) = b.next_admission(admitted)
            {
                admitted += 1;
                b.activate(ActiveSeq {
                    req_id: req.id, slot, last_token: 0, generated: 0,
                    max_new_tokens: req.params.max_new_tokens,
                    sampling: req.params.sampling(), stop_tokens: vec![],
                });
                if b.active_count() > cap {
                    return false;
                }
            }
            let act: Vec<_> = b.active_seqs().iter()
                .map(|s| s.slot).collect();
            for slot in act {
                let _ = b.advance(slot, 1);
            }
        }
        b.is_idle()
    });
}

#[test]
fn prop_batcher_cancels_never_leak_slots() {
    // any interleaving of submits, cancels (of queued OR active
    // requests), and engine iterations must drain to an idle batcher
    // with every slot returned — the invariant the engine's
    // cancellation path relies on
    fn iterate(b: &mut Batcher, live: &mut Vec<u64>) {
        let mut adm = 0;
        while let Admission::Admit(req, slot) = b.next_admission(adm) {
            adm += 1;
            b.activate(ActiveSeq {
                req_id: req.id, slot, last_token: 0, generated: 1,
                max_new_tokens: req.params.max_new_tokens,
                sampling: req.params.sampling(), stop_tokens: vec![],
            });
        }
        let act: Vec<_> = b.active_seqs().iter().map(|s| s.slot).collect();
        for slot in act {
            let id = b.slots.owner(slot).unwrap();
            if b.advance(slot, 1).is_some() {
                live.retain(|&x| x != id);
            }
        }
    }
    let gen = vec_of(usize_in(0, 4), 40);
    check(&Config { cases: 200, ..Default::default() }, &gen, |ops| {
        let mut b = Batcher::new(2);
        let mut rng = Rng::new(7);
        let mut next_id = 0u64;
        let mut live: Vec<u64> = Vec::new();
        for &op in ops {
            match op {
                0 | 1 => {
                    b.submit(mk_req(next_id, 3));
                    live.push(next_id);
                    next_id += 1;
                }
                2 => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.below(live.len() as u64) as usize;
                    let id = live.swap_remove(i);
                    // cancel wherever the request currently lives
                    if let Some(slot) = b.slot_of(id) {
                        b.abort(slot);
                    } else if b.cancel_queued(id).is_none() {
                        return false; // neither active nor queued: lost!
                    }
                }
                _ => iterate(&mut b, &mut live),
            }
        }
        let mut guard = 0;
        while !b.is_idle() {
            guard += 1;
            if guard > 10_000 {
                return false; // livelock
            }
            iterate(&mut b, &mut live);
        }
        live.is_empty() && b.slots.used() == 0
    });
}

// ------------------------------------------------------------ tokenizer ---

#[test]
fn prop_tokenizer_roundtrip_ascii() {
    let corpus = "the quick brown fox jumps over the lazy dog . ".repeat(30);
    let tok = Tokenizer::train(&corpus, 64);
    let gen = vec_of(usize_in(32, 126), 80)
        .map(|bytes| bytes.into_iter()
             .map(|b| b as u8 as char).collect::<String>());
    let mut rng = Rng::new(9);
    for _ in 0..300 {
        let s = gen.sample(&mut rng);
        assert_eq!(tok.decode(&tok.encode(&s)), s, "roundtrip failed: {s:?}");
    }
}

#[test]
fn prop_tokenizer_ids_in_vocab() {
    let tok = Tokenizer::train(&"state space model ".repeat(50), 100);
    let v = tok.vocab_size() as i32;
    let gen = vec_of(usize_in(0, 255), 60);
    check(&Config { cases: 200, ..Default::default() }, &gen, |bytes| {
        let s: String = bytes.iter()
            .map(|&b| b as u8 as char).collect();
        tok.encode(&s).iter().all(|&t| t >= 0 && t < v)
    });
}

// ------------------------------------------------------------------ json ---

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.range(-1_000_000, 1_000_000) as f64) / 8.0),
            3 => Json::Str((0..rng.below(12))
                .map(|_| (32 + rng.below(94)) as u8 as char)
                .collect()),
            4 => Json::Arr((0..rng.below(5))
                .map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj((0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect()),
        }
    }
    let mut rng = Rng::new(0x4A534F4Eu64);
    for _ in 0..500 {
        let j = random_json(&mut rng, 3);
        let s = j.to_string();
        let back = Json::parse(&s)
            .unwrap_or_else(|e| panic!("reparse failed on {s}: {e}"));
        assert_eq!(j, back, "roundtrip mismatch for {s}");
    }
}
