//! Plan/oracle parity sweep (ISSUE 4, DESIGN.md §7).
//!
//! The load-bearing claim of the lowering pipeline: executing through
//! built plans is **bitwise identical** to the pre-refactor
//! hand-scheduled forward (`M2_PLAN=off`) for prefill, continuation and
//! batched decode — across shape buckets, batch sizes and worker
//! counts. The planner may pick any tiling/fan-out/fusion it likes;
//! none of it may move a single bit of output.

use mamba2_serve::runtime::{Backend, CacheState, PlanMode,
                            ReferenceBackend};

fn planned(threads: usize) -> ReferenceBackend {
    ReferenceBackend::seeded("tiny", 0).unwrap()
        .with_threads(threads)
        .with_plan_mode(PlanMode::On)
}

fn oracle(threads: usize) -> ReferenceBackend {
    ReferenceBackend::seeded("tiny", 0).unwrap()
        .with_threads(threads)
        .with_plan_mode(PlanMode::Off)
}

fn prompt(len: usize, salt: usize) -> Vec<i32> {
    (0..len).map(|i| ((i * 37 + 11 * salt + 5) % 512) as i32).collect()
}

fn assert_prefill_eq(a: &mamba2_serve::runtime::PrefillOut,
                     b: &mamba2_serve::runtime::PrefillOut, tag: &str) {
    assert_eq!(a.logits.as_f32(), b.logits.as_f32(), "{tag}: logits");
    assert_eq!(a.cache.ssm.as_f32(), b.cache.ssm.as_f32(), "{tag}: ssm");
    assert_eq!(a.cache.conv.as_f32(), b.cache.conv.as_f32(),
               "{tag}: conv");
}

#[test]
fn prefill_parity_across_buckets_batches_threads() {
    for &threads in &[1usize, 4] {
        let p = planned(threads);
        let o = oracle(threads);
        for &t in &[16usize, 64, 256] {
            for &batch in &[1usize, 2] {
                let toks: Vec<i32> = (0..batch)
                    .flat_map(|b| prompt(t, b + 1))
                    .collect();
                let pa = p.prefill(&toks, batch).unwrap();
                let ob = o.prefill(&toks, batch).unwrap();
                assert_prefill_eq(&pa, &ob,
                                  &format!("t={t} b={batch} \
                                            threads={threads}"));
            }
        }
    }
}

#[test]
fn continuation_parity_and_chain_consistency() {
    let p = planned(4);
    let o = oracle(4);
    let toks = prompt(48, 7);
    // planned continuation == oracle continuation, segment by segment
    let p1 = p.prefill(&toks[..16], 1).unwrap();
    let o1 = o.prefill(&toks[..16], 1).unwrap();
    assert_prefill_eq(&p1, &o1, "seg1");
    let p2 = p.prefill_continue(&p1.cache, &toks[16..], 1).unwrap();
    let o2 = o.prefill_continue(&o1.cache, &toks[16..], 1).unwrap();
    assert_prefill_eq(&p2, &o2, "seg2");
    // and the planned chain still equals one planned joint forward
    let joint = p.prefill(&toks, 1).unwrap();
    let v = p.cfg().vocab_size;
    let jl = joint.logits.as_f32();
    assert_eq!(&jl[..16 * v], &p1.logits.as_f32()[..]);
    assert_eq!(&jl[16 * v..], &p2.logits.as_f32()[..]);
    assert_eq!(joint.cache.ssm.as_f32(), p2.cache.ssm.as_f32());
    assert_eq!(joint.cache.conv.as_f32(), p2.cache.conv.as_f32());
}

#[test]
fn batched_decode_parity_across_widths_and_threads() {
    for &threads in &[1usize, 4] {
        let p = planned(threads);
        let o = oracle(threads);
        for &bsz in &[1usize, 3, 16] {
            // distinct realistic slots from per-sequence prefills
            let mut cache = CacheState::zeros(p.cfg(), bsz);
            for s in 0..bsz {
                let (c1, _) =
                    p.prefill_any(&prompt(16 + 16 * (s % 2), s + 1))
                        .unwrap();
                cache.copy_slot_from(s, &c1, 0);
            }
            let tokens: Vec<i32> =
                (0..bsz).map(|i| ((i * 31 + 7) % 512) as i32).collect();
            let pa = p.decode_step(&cache, &tokens).unwrap();
            let ob = o.decode_step(&cache, &tokens).unwrap();
            assert_eq!(pa.logits.as_f32(), ob.logits.as_f32(),
                       "B={bsz} threads={threads}: logits");
            assert_eq!(pa.cache.ssm.as_f32(), ob.cache.ssm.as_f32(),
                       "B={bsz} threads={threads}: ssm");
            assert_eq!(pa.cache.conv.as_f32(), ob.cache.conv.as_f32(),
                       "B={bsz} threads={threads}: conv");
        }
    }
}

#[test]
fn full_generation_parity_with_ragged_prompt() {
    // prefill_any (greedy bucket chain + tail decode) and the decode
    // loop drive every planned entrypoint end-to-end; greedy outputs
    // must match the oracle token for token
    let p = planned(4);
    let o = oracle(4);
    let prompt = prompt(100, 3); // chains 64+16+16 then 4 tail steps
    let (pc, pl) = p.prefill_any(&prompt).unwrap();
    let (oc, ol) = o.prefill_any(&prompt).unwrap();
    assert_eq!(pl.as_f32(), ol.as_f32(), "prefill_any logits");
    assert_eq!(pc.ssm.as_f32(), oc.ssm.as_f32(), "prefill_any ssm");
    let first = mamba2_serve::runtime::argmax_last(&pl)[0];
    let (pg, _) = p.decode_loop(&pc, first, 16).unwrap();
    let (og, _) = o.decode_loop(&oc, first, 16).unwrap();
    assert_eq!(pg, og, "greedy generations diverged");
}

#[test]
fn forward_full_parity() {
    let p = planned(4);
    let o = oracle(4);
    let toks = prompt(64, 9);
    assert_eq!(p.forward_full(&toks).unwrap().as_f32(),
               o.forward_full(&toks).unwrap().as_f32());
}

#[test]
fn arena_reuse_stays_bitwise_across_repeats() {
    // PR 5: the executor runs every call on a recycled slab from the
    // plan's arena pool, returned DIRTY — correctness rests on every
    // op zero-filling or fully overwriting its output. Re-running the
    // same shapes (same slab, different stale contents each round, and
    // a ChunkScan crow carrying continuation seeds on round 2) must
    // reproduce the oracle bitwise every time.
    let p = planned(4);
    let o = oracle(4);
    let toks = prompt(48, 2);
    let want_pre = o.prefill(&toks[..32], 1).unwrap();
    let want_cont =
        o.prefill_continue(&want_pre.cache, &toks[32..], 1).unwrap();
    for round in 0..3 {
        let pre = p.prefill(&toks[..32], 1).unwrap();
        assert_eq!(pre.logits.as_f32(), want_pre.logits.as_f32(),
                   "round {round}: prefill");
        // continuation reuses the SAME plan+slab as a fresh 16-token
        // prefill (same shape key), with init seeds flowing through
        // the planned crow scratch — the dirtiest reuse pattern
        let cont = p.prefill_continue(&pre.cache, &toks[32..], 1)
            .unwrap();
        assert_eq!(cont.logits.as_f32(), want_cont.logits.as_f32(),
                   "round {round}: continuation");
        let fresh = p.prefill(&toks[32..48], 1).unwrap();
        let ofresh = o.prefill(&toks[32..48], 1).unwrap();
        assert_eq!(fresh.logits.as_f32(), ofresh.logits.as_f32(),
                   "round {round}: fresh prefill after continuation");
    }
    // decode: 16 repeated steps on one slab vs the oracle
    let (cache, last) = p.prefill_any(&toks[..32]).unwrap();
    let mut tok = mamba2_serve::runtime::argmax_last(&last)[0];
    let mut pc = cache.clone();
    let mut oc = cache;
    for step in 0..16 {
        let ps = p.decode_step(&pc, &[tok]).unwrap();
        let os = o.decode_step(&oc, &[tok]).unwrap();
        assert_eq!(ps.logits.as_f32(), os.logits.as_f32(),
                   "step {step}: logits");
        assert_eq!(ps.cache.ssm.as_f32(), os.cache.ssm.as_f32());
        tok = mamba2_serve::runtime::argmax_last(&ps.logits)[0];
        pc = ps.cache;
        oc = os.cache;
    }
}

// NOTE: the M2_PLAN env-var behaviour is tested in tests/plan_env.rs —
// its own test binary with a single test, because `std::env::set_var`
// racing the `env::var` reads of concurrently-running tests in the same
// process is undefined behaviour on glibc.
