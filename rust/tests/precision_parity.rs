//! Tolerance suite for the bf16 weight stream (ISSUE 5, DESIGN.md §8),
//! the paper's §4 parity protocol adapted to untrained sim configs.
//!
//! The bf16 path differs from f32 by exactly the weights' storage
//! rounding, so the contract has three parts:
//!
//!   * prefill is **bitwise** f32 in both modes (the pass is
//!     decode-only by default),
//!   * decode drift is bounded: per-step logit perturbation, hidden
//!     (ssm) state relative error and teacher-forced |ΔPPL| all stay
//!     within bounds calibrated ~5-10× above a float64 mirror of the
//!     model (see CHANGES.md PR 5 verification notes),
//!   * greedy decisions agree token-for-token over 64 steps at every
//!     step whose f32 top-2 margin exceeds the decision threshold
//!     (0.05, ≈8× the measured bf16 perturbation). Untrained sim
//!     configs emit near-uniform logits, so *unconditional* sequence
//!     equality would be a coin flip on sub-rounding margins — the
//!     paper's protocol compares trained checkpoints, where decisive
//!     margins dwarf storage rounding; the margin gate is that
//!     protocol made precise for random weights. The test also pins
//!     that the gate is far from vacuous (≳1/8 of steps decisive).
//!
//! PR 8 extends the same protocol to the SIMD kernel tier (DESIGN.md
//! §11): a vector tier re-orders lane-accumulated reductions and maps
//! `exp` to a ≲1-ulp polynomial, so scalar-vs-vector drift rides the
//! identical envelope — per-step |Δlogit| and relative-L2 bounds,
//! margin-gated greedy agreement, and per-ISA determinism. The
//! `simd_*` tests self-skip on hosts whose best tier IS scalar; exact
//! kernel-vs-lane-oracle parity lives in `tests/kernel_parity.rs`.
//!
//! PR 10 runs the group-quantised streams (int8 / q4, DESIGN.md §13)
//! through the same three-part contract with **per-dtype** envelopes:
//! prefill stays bitwise f32 under every `--weights` mode, decode
//! drift is bounded by limits scaled to each dtype's group-64
//! quantisation SNR (int8 ≈ 5× the bf16 rounding noise, q4 ≈ 100×),
//! and the margin-gated greedy protocol gains a per-dtype decision
//! threshold sized ≥ 2.5× the dtype's perturbation bound (so a
//! decisive step that diverges is a real contract break, not noise).
//! The decisive-step floor is still counted at the PR 5 gap on the
//! f32 trajectory — non-vacuousness is a property of the trajectory,
//! not of the comparison dtype. Exact kernel-vs-oracle parity of the
//! fused dequant kernels lives in `tests/kernel_parity.rs`.

use mamba2_serve::runtime::{argmax_last, Backend, PlanMode,
                            ReferenceBackend, WeightsDtype};
use mamba2_serve::tensor::kernels::Isa;

/// Decision threshold of the margin-gated greedy protocol; ≈8× the
/// mirrored max per-step |Δlogit| (0.006 tiny / 0.008 sim-130m).
const DECISIVE_GAP: f32 = 0.05;
/// Bound on the per-step logit perturbation along a teacher-forced
/// 64-step trajectory (mirror: ≤ 0.008).
const MAX_LOGIT_PERT: f32 = 0.05;
/// Bound on the relative L2 drift of logits and ssm state (mirror:
/// ≤ 0.012).
const MAX_REL_ERR: f64 = 0.05;
/// Bound on the teacher-forced perplexity shift (mirror: ≤ 0.16 at
/// PPL ≈ 515).
const MAX_DPPL: f64 = 1.0;

fn pair(config: &str, seed: u64) -> (ReferenceBackend, ReferenceBackend) {
    qpair(config, seed, WeightsDtype::Bf16)
}

/// f32 baseline + reduced-stream backend over the same seeded weights.
fn qpair(config: &str, seed: u64, dt: WeightsDtype)
    -> (ReferenceBackend, ReferenceBackend) {
    let f = ReferenceBackend::seeded(config, seed).unwrap()
        .with_plan_mode(PlanMode::On)
        .with_weights_dtype(WeightsDtype::F32);
    let b = ReferenceBackend::seeded(config, seed).unwrap()
        .with_plan_mode(PlanMode::On)
        .with_weights_dtype(dt);
    (f, b)
}

/// Per-dtype decode-drift envelope for the group-quantised streams
/// (DESIGN.md §13), scaled off the bf16 constants by each dtype's
/// group-64 quantisation SNR. Symmetric int8 at group 64 carries
/// ≈ 0.55% RMS weight error (≈ 5× bf16 storage rounding); q4 is a
/// 15-level code, ≈ 10% RMS (≈ 100× bf16). Bounds keep the same
/// ~6× headroom over the expected drift that PR 5 calibrated for
/// bf16; `gap` is the greedy decision threshold, ≥ 2.5× `pert` so a
/// decisive step cannot flip inside the drift budget.
struct QuantEnvelope {
    /// per-step max |Δlogit| along the teacher-forced trajectory
    pert: f32,
    /// relative L2 of logits and final ssm/conv state
    rel: f64,
    /// teacher-forced |Δ ln PPL| (log-perplexity shift)
    dln_ppl: f64,
    /// top-2 margin above which greedy picks must agree
    gap: f32,
}

fn quant_envelope(dt: WeightsDtype) -> QuantEnvelope {
    match dt {
        WeightsDtype::Int8 =>
            QuantEnvelope { pert: 0.3, rel: 0.25, dln_ppl: 0.5,
                            gap: 0.75 },
        // q4's rel bound sits above the ~1.41 decorrelation ceiling of
        // rel_l2 on same-scale signals: a 15-level code may legitimately
        // walk the teacher-forced state far from f32 on an untrained
        // model, and the gate here is "bounded, finite, same scale",
        // not closeness — closeness is int8's job
        WeightsDtype::Q4 =>
            QuantEnvelope { pert: 3.0, rel: 2.5, dln_ppl: 1.5,
                            gap: 7.5 },
        _ => unreachable!("envelopes exist for quantised streams only"),
    }
}

fn prompt(len: usize, salt: usize) -> Vec<i32> {
    (0..len).map(|i| ((i * 37 + 11 * salt + 11) % 512) as i32).collect()
}

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (*x as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

fn log_softmax(row: &[f32], idx: usize) -> f64 {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let z: f64 = row.iter().map(|&x| ((x as f64) - m).exp()).sum();
    (row[idx] as f64) - m - z.ln()
}

#[test]
fn bf16_prefill_is_bitwise_f32() {
    // decode-only precision: both modes run the identical f32 prefill
    for config in ["tiny", "sim-130m"] {
        let (f, b) = pair(config, 0);
        let toks = prompt(64, 1);
        let pf = f.prefill(&toks, 1).unwrap();
        let pb = b.prefill(&toks, 1).unwrap();
        assert_eq!(pf.logits.as_f32(), pb.logits.as_f32(), "{config}");
        assert_eq!(pf.cache.ssm.as_f32(), pb.cache.ssm.as_f32());
        assert_eq!(pf.cache.conv.as_f32(), pb.cache.conv.as_f32());
    }
}

#[test]
fn bf16_decode_drift_is_bounded_and_nonzero() {
    // teacher-forced 64-step trajectory from the shared (f32) prefill
    // state: logits move — but never past the calibrated bounds
    for (config, seed) in [("tiny", 0u64), ("tiny", 1), ("tiny", 2),
                           ("sim-130m", 0)] {
        let (f, b) = pair(config, seed);
        let p = prompt(32, seed as usize);
        let (cf, last) = f.prefill_any(&p).unwrap();
        let cb = cf.clone(); // identical start (prefill is f32-exact)
        let mut tok = argmax_last(&last)[0];
        let mut cf = cf;
        let mut cb = cb;
        let mut max_pert = 0.0f32;
        let mut max_rel = 0.0f64;
        for _ in 0..64 {
            let sf = f.decode_step(&cf, &[tok]).unwrap();
            let sb = b.decode_step(&cb, &[tok]).unwrap();
            max_pert = max_pert.max(sf.logits.max_abs_diff(&sb.logits));
            max_rel = max_rel.max(
                rel_l2(&sf.logits.as_f32(), &sb.logits.as_f32()));
            tok = argmax_last(&sf.logits)[0]; // f32 greedy trajectory
            cf = sf.cache;
            cb = sb.cache;
        }
        assert!(max_pert > 0.0, "{config}/{seed}: bf16 stream inert");
        assert!(max_pert < MAX_LOGIT_PERT,
                "{config}/{seed}: |Δlogit| {max_pert}");
        assert!(max_rel < MAX_REL_ERR,
                "{config}/{seed}: rel {max_rel}");
        let srel = rel_l2(&cf.ssm.as_f32(), &cb.ssm.as_f32());
        assert!(srel > 0.0 && srel < MAX_REL_ERR,
                "{config}/{seed}: ssm rel {srel}");
        // the conv window caches raw pre-activation inputs of the bf16
        // in_proj — drift there is bounded by the same envelope
        let crel = rel_l2(&cf.conv.as_f32(), &cb.conv.as_f32());
        assert!(crel < MAX_REL_ERR, "{config}/{seed}: conv rel {crel}");
    }
}

#[test]
fn bf16_greedy_margin_gated_agreement_over_64_steps() {
    for (config, seed) in [("tiny", 0u64), ("tiny", 3), ("sim-130m", 0)] {
        let (f, b) = pair(config, seed);
        let p = prompt(32, seed as usize);
        let (cache, last) = f.prefill_any(&p).unwrap();
        let mut cf = cache.clone();
        let mut cb = cache;
        let mut tok = argmax_last(&last)[0];
        let mut decisive = 0usize;
        for step in 0..64 {
            let sf = f.decode_step(&cf, &[tok]).unwrap();
            let sb = b.decode_step(&cb, &[tok]).unwrap();
            let row = sf.logits.as_f32();
            let t32 = argmax_last(&sf.logits)[0];
            let tbf = argmax_last(&sb.logits)[0];
            // top-2 margin of the f32 decision
            let top = row[t32 as usize];
            let second = row.iter().enumerate()
                .filter(|(i, _)| *i != t32 as usize)
                .map(|(_, &v)| v)
                .fold(f32::NEG_INFINITY, f32::max);
            if top - second > DECISIVE_GAP {
                decisive += 1;
                assert_eq!(t32, tbf,
                           "{config}/{seed} step {step}: decisive \
                            greedy pick diverged (gap {})",
                           top - second);
            }
            tok = t32;
            cf = sf.cache;
            cb = sb.cache;
        }
        // mirror: 19–29 of 64 steps decisive at this threshold — the
        // gate must stay far from vacuous
        assert!(decisive >= 8,
                "{config}/{seed}: only {decisive}/64 decisive steps");
    }
}

#[test]
fn bf16_teacher_forced_ppl_shift_is_bounded() {
    let (f, b) = pair("tiny", 0);
    let toks = prompt(48, 9);
    let nll = |backend: &ReferenceBackend| -> f64 {
        let (mut cache, mut logits) =
            backend.prefill_any(&toks[..16]).unwrap();
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for &t in &toks[16..] {
            // prefill_any and decode_step both return last-position
            // logits of shape (1, V), so the row IS the distribution
            let row = logits.as_f32();
            sum -= log_softmax(&row, t as usize);
            n += 1;
            let s = backend.decode_step(&cache, &[t]).unwrap();
            cache = s.cache;
            logits = s.logits;
        }
        sum / n as f64
    };
    let ppl_f = nll(&f).exp();
    let ppl_b = nll(&b).exp();
    // untrained 512-vocab model sits near uniform (ppl ≈ vocab)
    assert!(ppl_f > 100.0 && ppl_f < 2000.0, "ppl {ppl_f}");
    let dppl = (ppl_f - ppl_b).abs();
    assert!(dppl < MAX_DPPL, "|ΔPPL| {dppl} (f32 {ppl_f}, bf16 {ppl_b})");
    assert!(dppl > 0.0, "bf16 stream left the NLL bitwise unchanged");
}

/// Scalar-tier vs best-vector-tier backends over the same weights, or
/// `None` when the host has no vector tier (the `simd_*` tests then
/// self-skip — scalar-vs-scalar parity is vacuous and is already pinned
/// bitwise elsewhere).
fn simd_pair(config: &str, seed: u64)
    -> Option<(ReferenceBackend, ReferenceBackend)> {
    let isa = Isa::detect();
    if isa == Isa::Scalar {
        return None;
    }
    let s = ReferenceBackend::seeded(config, seed).unwrap()
        .with_isa(Isa::Scalar);
    let v = ReferenceBackend::seeded(config, seed).unwrap()
        .with_isa(isa);
    Some((s, v))
}

#[test]
fn simd_decode_drift_rides_the_bf16_envelope() {
    // teacher-forced 64-step trajectory on the scalar backend's greedy
    // tokens: whatever nodes the planner retiered, the vector tier may
    // move logits only by lane-reordered sums and ≲1-ulp exp — far
    // inside the envelope calibrated for bf16 storage rounding. (If the
    // planner retiered nothing at this shape, drift is 0 and the bounds
    // hold trivially — the retier decision itself is pinned in the
    // planner's unit tests.)
    for (config, seed) in [("tiny", 0u64), ("tiny", 1), ("tiny", 2)] {
        let Some((s, v)) = simd_pair(config, seed) else { return };
        let p = prompt(32, seed as usize);
        let ps = s.prefill(&p, 1).unwrap();
        let pv = v.prefill(&p, 1).unwrap();
        let prel = rel_l2(&ps.logits.as_f32(), &pv.logits.as_f32());
        assert!(prel < MAX_REL_ERR, "{config}/{seed}: prefill {prel}");
        let mut cs = ps.cache;
        let mut cv = pv.cache;
        let mut tok = argmax_last(&ps.logits)[0];
        let mut max_pert = 0.0f32;
        for _ in 0..64 {
            let ss = s.decode_step(&cs, &[tok]).unwrap();
            let sv = v.decode_step(&cv, &[tok]).unwrap();
            max_pert = max_pert.max(ss.logits.max_abs_diff(&sv.logits));
            tok = argmax_last(&ss.logits)[0];
            cs = ss.cache;
            cv = sv.cache;
        }
        assert!(max_pert < MAX_LOGIT_PERT,
                "{config}/{seed}: |Δlogit| {max_pert}");
        let srel = rel_l2(&cs.ssm.as_f32(), &cv.ssm.as_f32());
        assert!(srel < MAX_REL_ERR, "{config}/{seed}: ssm rel {srel}");
    }
}

#[test]
fn simd_greedy_margin_gated_agreement_over_64_steps() {
    // PR 5's margin-gated greedy protocol verbatim, with the vector
    // tier in the bf16 seat: every scalar-decisive step (top-2 margin
    // > DECISIVE_GAP) must pick the same token on the vector tier
    for (config, seed) in [("tiny", 0u64), ("tiny", 3)] {
        let Some((s, v)) = simd_pair(config, seed) else { return };
        let p = prompt(32, seed as usize);
        let (cache, last) = s.prefill_any(&p).unwrap();
        let (vcache, _) = v.prefill_any(&p).unwrap();
        let mut cs = cache;
        let mut cv = vcache;
        let mut tok = argmax_last(&last)[0];
        let mut decisive = 0usize;
        for step in 0..64 {
            let ss = s.decode_step(&cs, &[tok]).unwrap();
            let sv = v.decode_step(&cv, &[tok]).unwrap();
            let row = ss.logits.as_f32();
            let ts = argmax_last(&ss.logits)[0];
            let tv = argmax_last(&sv.logits)[0];
            let top = row[ts as usize];
            let second = row.iter().enumerate()
                .filter(|(i, _)| *i != ts as usize)
                .map(|(_, &x)| x)
                .fold(f32::NEG_INFINITY, f32::max);
            if top - second > DECISIVE_GAP {
                decisive += 1;
                assert_eq!(ts, tv,
                           "{config}/{seed} step {step}: decisive \
                            greedy pick diverged (gap {})",
                           top - second);
            }
            tok = ts;
            cs = ss.cache;
            cv = sv.cache;
        }
        // the decisive count is a property of the scalar trajectory —
        // same mirror calibration as the bf16 gate (19–29 of 64)
        assert!(decisive >= 8,
                "{config}/{seed}: only {decisive}/64 decisive steps");
    }
}

#[test]
fn simd_decode_is_deterministic_and_fusion_bounded() {
    // per-ISA determinism: the vector tier is a fixed per-node kernel
    // choice, so repeated runs are bitwise equal; and fused-vs-single
    // decode stays inside the drift envelope (b=1 and b=2 buckets are
    // priced independently, so their tiers — and low-order bits — may
    // legitimately differ, but never past the bounds)
    let Some((_, v)) = simd_pair("tiny", 0) else { return };
    let (c1, _) = v.prefill_any(&prompt(16, 1)).unwrap();
    let (c2, _) = v.prefill_any(&prompt(32, 2)).unwrap();
    let mut cache = mamba2_serve::runtime::CacheState::zeros(v.cfg(), 2);
    cache.copy_slot_from(0, &c1, 0);
    cache.copy_slot_from(1, &c2, 0);
    let fused = v.decode_step(&cache, &[5, 9]).unwrap();
    let again = v.decode_step(&cache, &[5, 9]).unwrap();
    assert_eq!(fused.logits.as_f32(), again.logits.as_f32(),
               "vector-tier decode must be deterministic");
    let s1 = v.decode_step(&c1, &[5]).unwrap();
    let s2 = v.decode_step(&c2, &[9]).unwrap();
    let vs = v.cfg().vocab_size;
    let all = fused.logits.as_f32();
    let r1 = rel_l2(&all[..vs], &s1.logits.as_f32());
    let r2 = rel_l2(&all[vs..], &s2.logits.as_f32());
    assert!(r1 < MAX_REL_ERR && r2 < MAX_REL_ERR,
            "fused-vs-single drift {r1} / {r2}");
    // a full prefill repeats bitwise too
    let p = prompt(64, 4);
    let a = v.prefill(&p, 1).unwrap();
    let b = v.prefill(&p, 1).unwrap();
    assert_eq!(a.logits.as_f32(), b.logits.as_f32());
}

#[test]
fn bf16_decode_is_deterministic_and_batch_consistent() {
    // the bf16 stream keeps the batched-step contract: B-fused decode
    // equals B independent single-slot decodes bitwise (rounding
    // happens at pack time, not per launch), and repeated runs agree
    let (_, b) = pair("tiny", 0);
    let (c1, _) = b.prefill_any(&prompt(16, 1)).unwrap();
    let (c2, _) = b.prefill_any(&prompt(32, 2)).unwrap();
    let mut cache = mamba2_serve::runtime::CacheState::zeros(b.cfg(), 2);
    cache.copy_slot_from(0, &c1, 0);
    cache.copy_slot_from(1, &c2, 0);
    let fused = b.decode_step(&cache, &[5, 9]).unwrap();
    let s1 = b.decode_step(&c1, &[5]).unwrap();
    let s2 = b.decode_step(&c2, &[9]).unwrap();
    let v = b.cfg().vocab_size;
    let all = fused.logits.as_f32();
    assert_eq!(&all[..v], &s1.logits.as_f32()[..]);
    assert_eq!(&all[v..], &s2.logits.as_f32()[..]);
    let again = b.decode_step(&cache, &[5, 9]).unwrap();
    assert_eq!(fused.logits.as_f32(), again.logits.as_f32());
}

#[test]
fn quantised_prefill_is_bitwise_f32() {
    // the quantisation pass is decode-only, like bf16: every
    // `--weights` mode runs the identical f32 prefill, bit for bit
    for dt in [WeightsDtype::Int8, WeightsDtype::Q4] {
        for config in ["tiny", "sim-130m"] {
            let (f, q) = qpair(config, 0, dt);
            let toks = prompt(64, 1);
            let pf = f.prefill(&toks, 1).unwrap();
            let pq = q.prefill(&toks, 1).unwrap();
            assert_eq!(pf.logits.as_f32(), pq.logits.as_f32(),
                       "{config}/{dt:?}");
            assert_eq!(pf.cache.ssm.as_f32(), pq.cache.ssm.as_f32());
            assert_eq!(pf.cache.conv.as_f32(), pq.cache.conv.as_f32());
        }
    }
}

#[test]
fn quantised_decode_drift_is_bounded_and_nonzero() {
    // PR 5's teacher-forced 64-step drift run, per-dtype envelope:
    // the quantised stream must move logits (the codes are not a
    // no-op) but stay inside the bound scaled to its group-64 SNR
    for dt in [WeightsDtype::Int8, WeightsDtype::Q4] {
        let env = quant_envelope(dt);
        for (config, seed) in [("tiny", 0u64), ("tiny", 1),
                               ("sim-130m", 0)] {
            let (f, q) = qpair(config, seed, dt);
            let p = prompt(32, seed as usize);
            let (cf, last) = f.prefill_any(&p).unwrap();
            let cq = cf.clone(); // identical start: prefill is f32
            let mut tok = argmax_last(&last)[0];
            let mut cf = cf;
            let mut cq = cq;
            let mut max_pert = 0.0f32;
            let mut max_rel = 0.0f64;
            for _ in 0..64 {
                let sf = f.decode_step(&cf, &[tok]).unwrap();
                let sq = q.decode_step(&cq, &[tok]).unwrap();
                max_pert =
                    max_pert.max(sf.logits.max_abs_diff(&sq.logits));
                max_rel = max_rel.max(
                    rel_l2(&sf.logits.as_f32(), &sq.logits.as_f32()));
                tok = argmax_last(&sf.logits)[0]; // f32 trajectory
                cf = sf.cache;
                cq = sq.cache;
            }
            assert!(max_pert > 0.0,
                    "{config}/{seed}/{dt:?}: quantised stream inert");
            assert!(max_pert < env.pert,
                    "{config}/{seed}/{dt:?}: |Δlogit| {max_pert}");
            assert!(max_rel < env.rel,
                    "{config}/{seed}/{dt:?}: rel {max_rel}");
            let srel = rel_l2(&cf.ssm.as_f32(), &cq.ssm.as_f32());
            assert!(srel > 0.0 && srel < env.rel,
                    "{config}/{seed}/{dt:?}: ssm rel {srel}");
            let crel = rel_l2(&cf.conv.as_f32(), &cq.conv.as_f32());
            assert!(crel < env.rel,
                    "{config}/{seed}/{dt:?}: conv rel {crel}");
        }
    }
}

#[test]
fn quantised_greedy_margin_gated_agreement_over_64_steps() {
    // the PR 5 protocol with a per-dtype decision threshold: any step
    // whose f32 top-2 margin clears the dtype's gap (≥ 2.5× its
    // perturbation bound) must pick the same token on the quantised
    // stream. The ≥8/64 decisive floor is still measured at the PR 5
    // gap — it pins that the *trajectory* stays far from uniform,
    // which is independent of the comparison dtype.
    for dt in [WeightsDtype::Int8, WeightsDtype::Q4] {
        let env = quant_envelope(dt);
        for (config, seed) in [("tiny", 0u64), ("tiny", 3)] {
            let (f, q) = qpair(config, seed, dt);
            let p = prompt(32, seed as usize);
            let (cache, last) = f.prefill_any(&p).unwrap();
            let mut cf = cache.clone();
            let mut cq = cache;
            let mut tok = argmax_last(&last)[0];
            let mut decisive_pr5 = 0usize;
            for step in 0..64 {
                let sf = f.decode_step(&cf, &[tok]).unwrap();
                let sq = q.decode_step(&cq, &[tok]).unwrap();
                let row = sf.logits.as_f32();
                let tf = argmax_last(&sf.logits)[0];
                let tq = argmax_last(&sq.logits)[0];
                let top = row[tf as usize];
                let second = row.iter().enumerate()
                    .filter(|(i, _)| *i != tf as usize)
                    .map(|(_, &v)| v)
                    .fold(f32::NEG_INFINITY, f32::max);
                let gap = top - second;
                if gap > DECISIVE_GAP {
                    decisive_pr5 += 1;
                }
                if gap > env.gap {
                    assert_eq!(tf, tq,
                               "{config}/{seed}/{dt:?} step {step}: \
                                decisive greedy pick diverged \
                                (gap {gap})");
                }
                tok = tf;
                cf = sf.cache;
                cq = sq.cache;
            }
            assert!(decisive_pr5 >= 8,
                    "{config}/{seed}/{dt:?}: only {decisive_pr5}/64 \
                     decisive steps");
        }
    }
}

#[test]
fn quantised_teacher_forced_ppl_shift_is_bounded() {
    // log-perplexity form of the PR 5 ΔPPL gate: robust to the larger
    // absolute shifts a 15-level q4 code legitimately produces on an
    // untrained near-uniform model
    for dt in [WeightsDtype::Int8, WeightsDtype::Q4] {
        let env = quant_envelope(dt);
        let (f, q) = qpair("tiny", 0, dt);
        let toks = prompt(48, 9);
        let nll = |backend: &ReferenceBackend| -> f64 {
            let (mut cache, mut logits) =
                backend.prefill_any(&toks[..16]).unwrap();
            let mut sum = 0.0f64;
            let mut n = 0usize;
            for &t in &toks[16..] {
                let row = logits.as_f32();
                sum -= log_softmax(&row, t as usize);
                n += 1;
                let s = backend.decode_step(&cache, &[t]).unwrap();
                cache = s.cache;
                logits = s.logits;
            }
            sum / n as f64
        };
        let nll_f = nll(&f);
        let nll_q = nll(&q);
        assert!(nll_q.is_finite() && nll_q > 0.0,
                "{dt:?}: quantised NLL {nll_q}");
        let dln = (nll_f - nll_q).abs(); // = |Δ ln PPL|
        assert!(dln < env.dln_ppl,
                "{dt:?}: |Δln PPL| {dln} (f32 {}, quantised {})",
                nll_f.exp(), nll_q.exp());
        assert!(dln > 0.0,
                "{dt:?}: quantised stream left the NLL unchanged");
    }
}

#[test]
fn quantised_decode_is_deterministic_and_batch_consistent() {
    // same contract as the bf16 stream: codes and scales are fixed at
    // pack time and the broadcast kernels treat batch rows
    // independently, so B-fused decode equals B single-slot decodes
    // bitwise and repeated runs agree
    for dt in [WeightsDtype::Int8, WeightsDtype::Q4] {
        let (_, q) = qpair("tiny", 0, dt);
        let (c1, _) = q.prefill_any(&prompt(16, 1)).unwrap();
        let (c2, _) = q.prefill_any(&prompt(32, 2)).unwrap();
        let mut cache =
            mamba2_serve::runtime::CacheState::zeros(q.cfg(), 2);
        cache.copy_slot_from(0, &c1, 0);
        cache.copy_slot_from(1, &c2, 0);
        let fused = q.decode_step(&cache, &[5, 9]).unwrap();
        let s1 = q.decode_step(&c1, &[5]).unwrap();
        let s2 = q.decode_step(&c2, &[9]).unwrap();
        let v = q.cfg().vocab_size;
        let all = fused.logits.as_f32();
        assert_eq!(&all[..v], &s1.logits.as_f32()[..], "{dt:?}");
        assert_eq!(&all[v..], &s2.logits.as_f32()[..], "{dt:?}");
        let again = q.decode_step(&cache, &[5, 9]).unwrap();
        assert_eq!(fused.logits.as_f32(), again.logits.as_f32(),
                   "{dt:?}");
    }
}
