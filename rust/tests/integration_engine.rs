//! Integration: continuous-batching engine end-to-end on the tiny config,
//! hermetically on the pure-Rust reference backend (no artifacts needed).
//! Covers the v2 request surface: `GenerateParams`, multiple stop tokens,
//! and cancellation (explicit, queued, and stream-drop) freeing slots
//! mid-decode.

use std::sync::Arc;
use std::time::{Duration, Instant};

use mamba2_serve::coordinator::{Engine, EngineConfig, Event, FinishReason,
                                GenRequest, GenerateParams, Router,
                                SingleStream};
use mamba2_serve::runtime::{Backend, ReferenceBackend};

fn session() -> Box<dyn Backend> {
    Box::new(ReferenceBackend::seeded("tiny", 0).unwrap())
}

#[test]
fn single_request_roundtrip() {
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    let stream = eng.generate(vec![1, 2, 3, 4, 5],
                              GenerateParams::new().max_new_tokens(8));
    let (toks, reason) = stream.collect_with_reason().unwrap();
    assert_eq!(toks.len(), 8);
    assert_eq!(reason, FinishReason::Length);
    let snap = eng.metrics.snapshot();
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.tokens_generated, 8);
    assert_eq!(snap.queue_depth, 0);
    assert_eq!(snap.in_flight, 0);
}

#[test]
fn batched_equals_single_stream_greedy() {
    // continuous batching must not change greedy outputs (batch
    // independence — the serving-level version of the paper's Fig. 5
    // batch-invariance claim)
    let sess = session();
    let ss = SingleStream::new(sess.as_ref());
    let prompts: Vec<Vec<i32>> = vec![
        (1..17).collect(),
        (40..56).collect(),
        (100..116).collect(),
    ];
    let mut want = Vec::new();
    for p in &prompts {
        want.push(ss.generate_host(p, 6).unwrap());
    }
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    let streams: Vec<_> = prompts.iter()
        .map(|p| eng.generate(p.clone(),
                              GenerateParams::new().max_new_tokens(6)))
        .collect();
    for (i, s) in streams.into_iter().enumerate() {
        let got = s.collect().unwrap();
        assert_eq!(got, want[i], "request {i} diverged under batching");
    }
}

#[test]
fn oversubscription_queues_and_completes() {
    // more requests than slots: all must complete
    let eng = Engine::start(session(), EngineConfig {
        batch_cap: 2,
        ..Default::default()
    }).unwrap();
    let streams: Vec<_> = (0..7)
        .map(|i| eng.generate(vec![i as i32 + 1; 8],
                              GenerateParams::new().max_new_tokens(5)))
        .collect();
    for s in streams {
        assert_eq!(s.collect().unwrap().len(), 5);
    }
    let snap = eng.metrics.snapshot();
    assert_eq!(snap.completed, 7);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.queue_depth, 0, "admitted must catch up to submitted");
    assert!(snap.mean_batch_occupancy > 1.0,
            "batching should overlap requests (occupancy {})",
            snap.mean_batch_occupancy);
}

#[test]
fn varying_lengths_join_and_leave() {
    // sequences of different generation lengths enter and retire at
    // different steps — the continuous part of continuous batching
    let eng = Engine::start(session(), EngineConfig {
        batch_cap: 4,
        ..Default::default()
    }).unwrap();
    let lens = [2usize, 9, 5, 13, 1, 7];
    let streams: Vec<_> = lens.iter().enumerate()
        .map(|(i, &n)| eng.generate(vec![(i + 1) as i32; 4],
                                    GenerateParams::new()
                                        .max_new_tokens(n)))
        .collect();
    for (s, &n) in streams.into_iter().zip(&lens) {
        assert_eq!(s.collect().unwrap().len(), n);
    }
}

#[test]
fn topk_sampling_is_seeded_and_valid() {
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    let params = GenerateParams::new().max_new_tokens(6).top_k(4).seed(7);
    let a = eng.submit_req(GenRequest {
        id: 900, prompt: vec![1, 2, 3], params: params.clone(),
    }).collect().unwrap();
    let b = eng.submit_req(GenRequest {
        id: 901, prompt: vec![1, 2, 3], params,
    }).collect().unwrap();
    assert_eq!(a, b, "same seed must reproduce");
    let vocab = 512;
    assert!(a.iter().all(|&t| t >= 0 && t < vocab));
}

#[test]
fn topp_sampling_is_seeded_and_valid() {
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    let params = GenerateParams::new().max_new_tokens(6).top_p(0.9)
        .temperature(0.8).seed(11);
    let a = eng.generate(vec![4, 5, 6], params.clone()).collect().unwrap();
    let b = eng.generate(vec![4, 5, 6], params).collect().unwrap();
    assert_eq!(a, b, "same seed must reproduce");
    assert!(a.iter().all(|&t| t >= 0 && t < 512));
}

#[test]
fn long_prompt_uses_bucket_plus_steps() {
    // prompt length 23 = bucket 16 + 7 steps; must still match the
    // host-loop reference built on the same policy
    let sess = session();
    let ss = SingleStream::new(sess.as_ref());
    let prompt: Vec<i32> = (1..24).collect();
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    let got = eng.generate(prompt.clone(),
                           GenerateParams::new().max_new_tokens(5))
        .collect().unwrap();
    let want = ss.generate_host(&prompt, 5).unwrap();
    assert_eq!(got, want);
}

#[test]
fn router_balances_across_replicas() {
    let r1 = Arc::new(Engine::start(session(),
                                    EngineConfig::default()).unwrap());
    let r2 = Arc::new(Engine::start(session(),
                                    EngineConfig::default()).unwrap());
    let router = Router::new(vec![r1, r2]);
    let streams: Vec<_> = (0..6)
        .map(|_| router.generate(vec![1, 2, 3],
                                 GenerateParams::new().max_new_tokens(3)))
        .collect();
    for s in streams {
        assert_eq!(s.collect().unwrap().len(), 3);
    }
    assert_eq!(router.total_completed(), 6);
    // both replicas saw work
    let c0 = router.replica(0).metrics.snapshot().completed;
    let c1 = router.replica(1).metrics.snapshot().completed;
    assert!(c0 > 0 && c1 > 0, "load balancing failed: {c0}/{c1}");
}

#[test]
fn stop_token_ends_generation_early() {
    let sess = session();
    let ss = SingleStream::new(sess.as_ref());
    // find what greedy generates, then use its 3rd token as stop
    let prompt: Vec<i32> = (1..17).collect();
    let ref_gen = ss.generate_host(&prompt, 8).unwrap();
    let stop = ref_gen[2];
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    let (got, reason) = eng.generate(prompt,
        GenerateParams::new().max_new_tokens(8).stop_token(stop))
        .collect_with_reason().unwrap();
    assert_eq!(got.len(), 3);
    assert_eq!(*got.last().unwrap(), stop);
    assert_eq!(reason, FinishReason::StopToken);
}

#[test]
fn any_of_multiple_stop_tokens_ends_generation() {
    let sess = session();
    let ss = SingleStream::new(sess.as_ref());
    let prompt: Vec<i32> = (1..17).collect();
    let ref_gen = ss.generate_host(&prompt, 8).unwrap();
    // the earliest of the two stops wins
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    let (got, reason) = eng.generate(prompt,
        GenerateParams::new().max_new_tokens(8)
            .stop_token(ref_gen[4]).stop_token(ref_gen[1]))
        .collect_with_reason().unwrap();
    assert_eq!(got.len(), 2);
    assert_eq!(*got.last().unwrap(), ref_gen[1]);
    assert_eq!(reason, FinishReason::StopToken);
}

// ------------------------------------------------------- cancellation ---

/// Poll a metrics counter until it reaches `want` (engine side-effects
/// are asynchronous to the test thread).
fn wait_for(mut get: impl FnMut() -> u64, want: u64, what: &str) {
    let t0 = Instant::now();
    while get() < want {
        assert!(t0.elapsed() < Duration::from_secs(30),
                "timed out waiting for {what} >= {want} (at {})", get());
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn cancel_mid_decode_frees_slot_and_counts() {
    // batch_cap 1: if the cancelled request leaked its slot, the second
    // request could never be admitted and this test would time out
    let eng = Engine::start(session(), EngineConfig {
        batch_cap: 1,
        ..Default::default()
    }).unwrap();
    let huge = 100_000;
    let mut s = eng.generate(vec![1, 2, 3, 4],
                             GenerateParams::new().max_new_tokens(huge));
    // wait until it is actually decoding
    match s.next_event() {
        Some(Event::Tokens(t)) => assert!(!t.is_empty()),
        other => panic!("expected first tokens, got {other:?}"),
    }
    s.cancel();
    // buffered tokens may still arrive, then the cancelled terminal event
    let mut reason = None;
    while let Some(ev) = s.next_event() {
        if let Event::Done { reason: r, .. } = ev {
            reason = Some(r);
        }
    }
    assert_eq!(reason, Some(FinishReason::Cancelled));
    // slot reuse: a fresh request completes on the single slot
    let out = eng.generate(vec![5, 6],
                           GenerateParams::new().max_new_tokens(3))
        .collect().unwrap();
    assert_eq!(out.len(), 3);
    let snap = eng.metrics.snapshot();
    assert_eq!(snap.cancelled, 1);
    assert_eq!(snap.completed, 1);
    assert!(snap.tokens_generated < huge as u64 / 2,
            "cancel must land long before max_new_tokens \
             (generated {})", snap.tokens_generated);
    assert_eq!(snap.in_flight, 0);
}

#[test]
fn dropped_stream_cancels_and_frees_slot() {
    let eng = Engine::start(session(), EngineConfig {
        batch_cap: 1,
        ..Default::default()
    }).unwrap();
    let mut s = eng.generate(vec![7, 8, 9],
                             GenerateParams::new().max_new_tokens(100_000));
    // ensure it was admitted before abandoning it
    assert!(matches!(s.next_event(), Some(Event::Tokens(_))));
    drop(s); // drop IS the cancel signal
    wait_for(|| eng.metrics.snapshot().cancelled, 1, "requests_cancelled");
    // the slot must be free again for new work
    let out = eng.generate(vec![1],
                           GenerateParams::new().max_new_tokens(2))
        .collect().unwrap();
    assert_eq!(out.len(), 2);
}

#[test]
fn cancel_of_queued_request_removes_it_before_prefill() {
    let eng = Engine::start(session(), EngineConfig {
        batch_cap: 1,
        ..Default::default()
    }).unwrap();
    // slot hog
    let mut hog = eng.generate(vec![1, 2],
                               GenerateParams::new()
                                   .max_new_tokens(100_000));
    assert!(matches!(hog.next_event(), Some(Event::Tokens(_))));
    // queued behind the hog
    let queued = eng.generate(vec![3, 4],
                              GenerateParams::new().max_new_tokens(5));
    queued.cancel();
    let (toks, reason) = queued.collect_with_reason().unwrap();
    assert!(toks.is_empty(), "queue-cancelled request generated tokens");
    assert_eq!(reason, FinishReason::Cancelled);
    hog.cancel();
    while hog.next_event().is_some() {}
    let snap = eng.metrics.snapshot();
    assert_eq!(snap.cancelled, 2);
    assert_eq!(snap.queue_depth, 0,
               "queue-cancel must keep queue_depth exact");
    assert_eq!(snap.in_flight, 0);
}

#[test]
fn cancel_unknown_id_is_a_noop() {
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    eng.cancel(424242); // must not disturb anything
    let out = eng.generate(vec![1, 2],
                           GenerateParams::new().max_new_tokens(3))
        .collect().unwrap();
    assert_eq!(out.len(), 3);
    assert_eq!(eng.metrics.snapshot().cancelled, 0);
}
