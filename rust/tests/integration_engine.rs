//! Integration: continuous-batching engine end-to-end on the tiny config,
//! hermetically on the pure-Rust reference backend (no artifacts needed).

use std::sync::Arc;

use mamba2_serve::coordinator::{Engine, EngineConfig, Router, Sampling,
                                SingleStream};
use mamba2_serve::runtime::{Backend, ReferenceBackend};

fn session() -> Box<dyn Backend> {
    Box::new(ReferenceBackend::seeded("tiny", 0).unwrap())
}

#[test]
fn single_request_roundtrip() {
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    let stream = eng.submit(vec![1, 2, 3, 4, 5], 8, Sampling::Greedy);
    let toks = stream.collect().unwrap();
    assert_eq!(toks.len(), 8);
    let snap = eng.metrics.snapshot();
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.tokens_generated, 8);
}

#[test]
fn batched_equals_single_stream_greedy() {
    // continuous batching must not change greedy outputs (batch
    // independence — the serving-level version of the paper's Fig. 5
    // batch-invariance claim)
    let sess = session();
    let ss = SingleStream::new(sess.as_ref());
    let prompts: Vec<Vec<i32>> = vec![
        (1..17).collect(),
        (40..56).collect(),
        (100..116).collect(),
    ];
    let mut want = Vec::new();
    for p in &prompts {
        want.push(ss.generate_host(p, 6).unwrap());
    }
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    let streams: Vec<_> = prompts.iter()
        .map(|p| eng.submit(p.clone(), 6, Sampling::Greedy))
        .collect();
    for (i, s) in streams.into_iter().enumerate() {
        let got = s.collect().unwrap();
        assert_eq!(got, want[i], "request {i} diverged under batching");
    }
}

#[test]
fn oversubscription_queues_and_completes() {
    // more requests than slots: all must complete
    let eng = Engine::start(session(), EngineConfig {
        batch_cap: 2,
        ..Default::default()
    }).unwrap();
    let streams: Vec<_> = (0..7)
        .map(|i| eng.submit(vec![i as i32 + 1; 8], 5, Sampling::Greedy))
        .collect();
    for s in streams {
        assert_eq!(s.collect().unwrap().len(), 5);
    }
    let snap = eng.metrics.snapshot();
    assert_eq!(snap.completed, 7);
    assert_eq!(snap.failed, 0);
    assert!(snap.mean_batch_occupancy > 1.0,
            "batching should overlap requests (occupancy {})",
            snap.mean_batch_occupancy);
}

#[test]
fn varying_lengths_join_and_leave() {
    // sequences of different generation lengths enter and retire at
    // different steps — the continuous part of continuous batching
    let eng = Engine::start(session(), EngineConfig {
        batch_cap: 4,
        ..Default::default()
    }).unwrap();
    let lens = [2usize, 9, 5, 13, 1, 7];
    let streams: Vec<_> = lens.iter().enumerate()
        .map(|(i, &n)| eng.submit(vec![(i + 1) as i32; 4], n,
                                  Sampling::Greedy))
        .collect();
    for (s, &n) in streams.into_iter().zip(&lens) {
        assert_eq!(s.collect().unwrap().len(), n);
    }
}

#[test]
fn topk_sampling_is_seeded_and_valid() {
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    let a = eng.submit_req(mamba2_serve::coordinator::GenRequest {
        id: 900, prompt: vec![1, 2, 3], max_new_tokens: 6,
        sampling: Sampling::TopK { k: 4, seed: 7 }, stop_token: None,
    }).collect().unwrap();
    let b = eng.submit_req(mamba2_serve::coordinator::GenRequest {
        id: 900, prompt: vec![1, 2, 3], max_new_tokens: 6,
        sampling: Sampling::TopK { k: 4, seed: 7 }, stop_token: None,
    }).collect().unwrap();
    assert_eq!(a, b, "same seed must reproduce");
    let vocab = 512;
    assert!(a.iter().all(|&t| t >= 0 && t < vocab));
}

#[test]
fn long_prompt_uses_bucket_plus_steps() {
    // prompt length 23 = bucket 16 + 7 steps; must still match the
    // host-loop reference built on the same policy
    let sess = session();
    let ss = SingleStream::new(sess.as_ref());
    let prompt: Vec<i32> = (1..24).collect();
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    let got = eng.submit(prompt.clone(), 5, Sampling::Greedy)
        .collect().unwrap();
    let want = ss.generate_host(&prompt, 5).unwrap();
    assert_eq!(got, want);
}

#[test]
fn router_balances_across_replicas() {
    let r1 = Arc::new(Engine::start(session(),
                                    EngineConfig::default()).unwrap());
    let r2 = Arc::new(Engine::start(session(),
                                    EngineConfig::default()).unwrap());
    let router = Router::new(vec![r1, r2]);
    let streams: Vec<_> = (0..6)
        .map(|_| router.submit(vec![1, 2, 3], 3, Sampling::Greedy))
        .collect();
    for s in streams {
        assert_eq!(s.collect().unwrap().len(), 3);
    }
    assert_eq!(router.total_completed(), 6);
    // both replicas saw work
    let c0 = router.replica(0).metrics.snapshot().completed;
    let c1 = router.replica(1).metrics.snapshot().completed;
    assert!(c0 > 0 && c1 > 0, "load balancing failed: {c0}/{c1}");
}

#[test]
fn stop_token_ends_generation_early() {
    let sess = session();
    let ss = SingleStream::new(sess.as_ref());
    // find what greedy generates, then use its 3rd token as stop
    let prompt: Vec<i32> = (1..17).collect();
    let ref_gen = ss.generate_host(&prompt, 8).unwrap();
    let stop = ref_gen[2];
    let eng = Engine::start(session(), EngineConfig::default()).unwrap();
    let got = eng.submit_req(mamba2_serve::coordinator::GenRequest {
        id: 1, prompt, max_new_tokens: 8, sampling: Sampling::Greedy,
        stop_token: Some(stop),
    }).collect().unwrap();
    assert_eq!(got.len(), 3);
    assert_eq!(*got.last().unwrap(), stop);
}
