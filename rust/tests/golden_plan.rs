//! Golden `plan_dump` check (ISSUE 4): the default config's textual
//! plan is pinned under `tests/goldens/`, so any change to the
//! planner's schedule — tiling, fan-out, fusion, cost accounting, IR
//! shape — shows up as a reviewable diff instead of a silent drift.
//!
//! The dump is a pure function of `(config, shape key, threads)`:
//! integer-only payload, worker count pinned to 8 here, so the text is
//! identical on every machine. Regenerate deliberately with
//!
//! ```bash
//! UPDATE_GOLDENS=1 cargo test --test golden_plan
//! ```
//!
//! and commit the diff.

use mamba2_serve::runtime::{Backend, FuseMode, PlanMode,
                            ReferenceBackend};
use mamba2_serve::tensor::kernels::Isa;

const GOLDEN: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/goldens/plan_sim-130m.txt");

fn current_dump() -> String {
    // ISA pinned to scalar and the fusion-region pass pinned on, so the
    // golden text stays host- and environment-independent even when the
    // suite runs with M2_ISA=auto or M2_FUSE=off in the environment
    let b = ReferenceBackend::seeded("sim-130m", 0).unwrap()
        .with_threads(8)
        .with_isa(Isa::Scalar)
        .with_fuse(FuseMode::On)
        .with_plan_mode(PlanMode::On);
    let prefill = b.plan_dump("prefill", 512, 1).expect("prefill plan");
    let decode = b.plan_dump("decode_step", 1, 16).expect("decode plan");
    format!("{prefill}\n{decode}")
}

#[test]
fn plan_dump_matches_golden() {
    let got = current_dump();
    if std::env::var("UPDATE_GOLDENS").is_ok() {
        std::fs::write(GOLDEN, &got).expect("write golden");
        return;
    }
    let want = std::fs::read_to_string(GOLDEN)
        .expect("read tests/goldens/plan_sim-130m.txt");
    if got != want {
        // line-level report so a schedule change reads as a diff
        for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
            assert_eq!(g, w, "plan dump diverges at line {}", i + 1);
        }
        assert_eq!(got.lines().count(), want.lines().count(),
                   "plan dump length changed");
        panic!("plan dump differs from golden (whitespace?)");
    }
}

#[test]
fn golden_covers_both_entrypoints() {
    let want = std::fs::read_to_string(GOLDEN).expect("golden exists");
    assert!(want.contains("plan sim-130m prefill b=1 t=512"));
    assert!(want.contains("plan sim-130m decode_step b=16"));
    // the pinned schedule is cost-derived, not hard-coded: the planner
    // chose parallel row blocks for the big contractions and chunk
    // tiles for the SSD stages
    assert!(want.contains("row_block="));
    assert!(want.contains("dispatches="));
    // PR 9: fixed fuse flags became cost-chosen fusion regions; both
    // pinned shapes fuse (the schedule line counts regions, member
    // nodes carry their region index)
    assert!(want.contains(" regions="));
    assert!(!want.contains(" regions=0 "));
    assert!(want.contains(" region="));
    assert!(!want.contains("fused-acc"));
    // PR 5: the precision/layout half of the schedule is pinned too —
    // prefill weights repacked into L1 panels, decode (16 rows, under
    // the repack threshold) dense, everything f32 by default
    assert!(want.contains("weights=f32 layout=tile32"));
    assert!(want.contains("weights=f32 layout=dense"));
    assert!(want.contains("w=f32.tile32"));
    assert!(want.contains("w=f32.tile16"));
    // PR 8: the kernel tier is part of the pinned schedule; the golden
    // is scalar-tier, so no per-node isa tags may appear
    assert!(want.contains("layout=tile32 isa=scalar"));
    assert!(want.contains("layout=dense isa=scalar"));
    assert!(!want.contains("isa=avx2") && !want.contains("isa=neon"));
}
