//! Seeded ragged-shape sweeps pinning the vector kernel tiers against
//! their scalar / lane-ordered oracles (PR 8, DESIGN.md §11) — the CI
//! "kernel parity" step's target.
//!
//! Two contracts, both **bitwise** (no tolerances anywhere in this
//! file):
//!
//!   * broadcast-A forms (`matmul_acc_*`, `axpy`, `add_assign`,
//!     `scan_carry`) are j-vectorised — one mul + one add per element in
//!     scalar order — so every tier must equal the scalar loops exactly;
//!   * dot/reduction forms (`matmul_bt_*`, `dot`, the rmsnorm variance)
//!     accumulate across k in SIMD lanes; their pinned reordering is the
//!     fold-in-halves model of `dot_lanes`/`sum_sq_lanes`, and the
//!     transcendental rows are a `silu_poly` map — all reproducible in
//!     portable scalar code, which is what the oracles here are.
//!
//! Shapes are deliberately ragged: every (m, k, n) sweep crosses the
//! 8-lane (AVX2) and 4-lane (NEON) boundaries so remainder tails, short
//! rows (k < lanes) and strided views all get hit. On a host whose best
//! tier IS scalar the sweeps still run (dispatch == oracle trivially),
//! so the binary never reports a skip CI could mistake for coverage.

use mamba2_serve::tensor::kernels::{bf16_to_f32, dot_lanes, pack_cols,
                                    q4_code, q4_row_bytes, quant_groups,
                                    quantize_i8_rows, quantize_q4_rows,
                                    silu, silu_poly, sum_sq_lanes,
                                    to_bf16, Dispatch, Isa};
use mamba2_serve::util::prng::Rng;

const SWEEPS: usize = 60;

fn lanes(isa: Isa) -> usize {
    match isa {
        Isa::Scalar => 1,
        Isa::Avx2 => 8,
        Isa::Neon => 4,
    }
}

fn vecf(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

/// Ragged (m, k, n): small enough to sweep densely, wide enough that k
/// and n cross every lane boundary (1..=19 covers 8·2+tail).
fn mkn(rng: &mut Rng) -> (usize, usize, usize) {
    (rng.range(1, 8) as usize,
     rng.range(1, 20) as usize,
     rng.range(1, 20) as usize)
}

#[test]
fn broadcast_matmuls_are_bitwise_scalar_on_ragged_strided_shapes() {
    let dx = Dispatch::new(Isa::detect());
    let or = Dispatch::scalar();
    let mut rng = Rng::new(0x5EED_0001);
    for sweep in 0..SWEEPS {
        let (m, k, n) = mkn(&mut rng);
        let lda = k + rng.range(0, 5) as usize;
        let ldc = n + rng.range(0, 5) as usize;
        let a = vecf(&mut rng, (m - 1) * lda + k, 1.0);
        let b = vecf(&mut rng, k * n, 1.0);
        let c0 = vecf(&mut rng, (m - 1) * ldc + n, 0.5);
        let tag = format!("sweep {sweep}: m={m} k={k} n={n} \
                           lda={lda} ldc={ldc}");

        let (mut cv, mut cs) = (c0.clone(), c0.clone());
        dx.matmul_acc_strided(&a, lda, &b, m, k, n, &mut cv, ldc);
        or.matmul_acc_strided(&a, lda, &b, m, k, n, &mut cs, ldc);
        assert_eq!(cv, cs, "dense: {tag}");

        let tile = rng.range(1, n as i64 + 3) as usize;
        let panels = pack_cols(&b, k, n, tile);
        let (mut cv, mut cs) = (c0.clone(), c0.clone());
        dx.matmul_acc_packed(&a, lda, &panels, tile, m, k, n, &mut cv,
                             ldc);
        or.matmul_acc_packed(&a, lda, &panels, tile, m, k, n, &mut cs,
                             ldc);
        assert_eq!(cv, cs, "packed tile={tile}: {tag}");

        let bh = to_bf16(&b);
        let (mut cv, mut cs) = (c0.clone(), c0);
        dx.matmul_acc_strided_bf16(&a, lda, &bh, m, k, n, &mut cv, ldc);
        or.matmul_acc_strided_bf16(&a, lda, &bh, m, k, n, &mut cs, ldc);
        assert_eq!(cv, cs, "bf16: {tag}");
    }
}

/// Ragged quantisation group: crosses lane multiples (8, 16), odd
/// widths that force the vector tiers onto their scalar-body fallback,
/// and groups wider than the row (one scale per row).
fn group_of(rng: &mut Rng) -> usize {
    rng.range(1, 24) as usize
}

#[test]
fn quantised_broadcast_matmuls_are_bitwise_scalar_on_ragged_shapes() {
    // the int8/q4 broadcast kernels dequantise in-kernel with the same
    // per-element op order on every tier (widen → ·scale → ·a → add),
    // and non-lane-multiple groups take the scalar body — so every
    // tier must equal the scalar loops exactly, at every group size
    let dx = Dispatch::new(Isa::detect());
    let or = Dispatch::scalar();
    let mut rng = Rng::new(0x5EED_0006);
    for sweep in 0..SWEEPS {
        let (m, k, n) = mkn(&mut rng);
        let lda = k + rng.range(0, 5) as usize;
        let ldc = n + rng.range(0, 5) as usize;
        let group = group_of(&mut rng);
        let a = vecf(&mut rng, (m - 1) * lda + k, 1.0);
        let b = vecf(&mut rng, k * n, 1.0);
        let c0 = vecf(&mut rng, (m - 1) * ldc + n, 0.5);
        let tag = format!("sweep {sweep}: m={m} k={k} n={n} \
                           lda={lda} ldc={ldc} g={group}");

        let (codes, scales) = quantize_i8_rows(&b, k, n, group);
        let (mut cv, mut cs) = (c0.clone(), c0.clone());
        dx.matmul_acc_strided_i8(&a, lda, &codes, &scales, group, m, k,
                                 n, &mut cv, ldc);
        or.matmul_acc_strided_i8(&a, lda, &codes, &scales, group, m, k,
                                 n, &mut cs, ldc);
        assert_eq!(cv, cs, "int8: {tag}");

        let (codes, scales) = quantize_q4_rows(&b, k, n, group);
        let (mut cv, mut cs) = (c0.clone(), c0);
        dx.matmul_acc_strided_q4(&a, lda, &codes, &scales, group, m, k,
                                 n, &mut cv, ldc);
        or.matmul_acc_strided_q4(&a, lda, &codes, &scales, group, m, k,
                                 n, &mut cs, ldc);
        assert_eq!(cv, cs, "q4: {tag}");
    }
}

#[test]
fn quantised_bt_matmuls_match_the_dequantised_lane_oracle() {
    // dot-form contract: when the group vectorises (group % lanes == 0)
    // the tier's pinned reordering is dot_lanes over the dequantised
    // row; otherwise the kernel takes its scalar body, i.e. the
    // sequential (1-lane) dot. Widen and ·scale are per-element, so
    // "dequantise then dot" reproduces the in-kernel order exactly.
    let dx = Dispatch::new(Isa::detect());
    let lane = lanes(dx.isa);
    let mut rng = Rng::new(0x5EED_0007);
    for sweep in 0..SWEEPS {
        let (m, k, n) = mkn(&mut rng);
        let lda = k + rng.range(0, 5) as usize;
        let ldc = n + rng.range(0, 5) as usize;
        let group = group_of(&mut rng);
        let eff = if group % lane == 0 { lane } else { 1 };
        let a = vecf(&mut rng, (m - 1) * lda + k, 1.0);
        let bt = vecf(&mut rng, n * k, 1.0); // (n, k) row-major
        let c0 = vecf(&mut rng, (m - 1) * ldc + n, 0.5);
        let tag = format!("sweep {sweep}: m={m} k={k} n={n} g={group} \
                           eff_lanes={eff}");

        let oracle = |deq_row: &dyn Fn(usize) -> Vec<f32>| -> Vec<f32> {
            let mut c = c0.clone();
            for i in 0..m {
                let ar = &a[i * lda..i * lda + k];
                for j in 0..n {
                    c[i * ldc + j] += dot_lanes(ar, &deq_row(j), eff);
                }
            }
            c
        };

        let (codes, scales) = quantize_i8_rows(&bt, n, k, group);
        let gpr = quant_groups(k, group);
        let want = oracle(&|j| {
            codes[j * k..(j + 1) * k].iter().enumerate()
                .map(|(t, &q)| q as f32 * scales[j * gpr + t / group])
                .collect()
        });
        let mut c = c0.clone();
        dx.matmul_bt_acc_strided_i8(&a, lda, &codes, &scales, group, m,
                                    k, n, &mut c, ldc);
        assert_eq!(c, want, "bt int8: {tag}");

        let (codes, scales) = quantize_q4_rows(&bt, n, k, group);
        let bpr = q4_row_bytes(k);
        let want = oracle(&|j| {
            let row = &codes[j * bpr..(j + 1) * bpr];
            (0..k).map(|t| {
                q4_code(row, t) as f32 * scales[j * gpr + t / group]
            }).collect()
        });
        let mut c = c0.clone();
        dx.matmul_bt_acc_strided_q4(&a, lda, &codes, &scales, group, m,
                                    k, n, &mut c, ldc);
        assert_eq!(c, want, "bt q4: {tag}");
    }
}

#[test]
fn elementwise_kernels_are_bitwise_scalar_on_ragged_lengths() {
    let dx = Dispatch::new(Isa::detect());
    let or = Dispatch::scalar();
    let mut rng = Rng::new(0x5EED_0002);
    for len in 1..=40 {
        let x = vecf(&mut rng, len, 1.5);
        let y0 = vecf(&mut rng, len, 0.5);
        let alpha = (rng.normal() * 0.7) as f32;

        let (mut yv, mut ys) = (y0.clone(), y0.clone());
        dx.axpy(alpha, &x, &mut yv);
        or.axpy(alpha, &x, &mut ys);
        assert_eq!(yv, ys, "axpy len={len}");

        let (mut yv, mut ys) = (y0.clone(), y0.clone());
        dx.add_assign(&mut yv, &x);
        or.add_assign(&mut ys, &x);
        assert_eq!(yv, ys, "add_assign len={len}");

        let decay = (rng.f64() * 0.99) as f32;
        let (mut yv, mut ys) = (y0.clone(), y0);
        dx.scan_carry(&mut yv, decay, &x);
        or.scan_carry(&mut ys, decay, &x);
        assert_eq!(yv, ys, "scan_carry len={len}");
    }
}

#[test]
fn dot_form_matmuls_match_the_lane_oracle_per_element() {
    let isa = Isa::detect();
    let dx = Dispatch::new(isa);
    let lane = lanes(dx.isa);
    let mut rng = Rng::new(0x5EED_0003);
    for sweep in 0..SWEEPS {
        let (m, k, n) = mkn(&mut rng);
        let lda = k + rng.range(0, 5) as usize;
        let ldc = n + rng.range(0, 5) as usize;
        let a = vecf(&mut rng, (m - 1) * lda + k, 1.0);
        let bt = vecf(&mut rng, n * k, 1.0); // (n, k) row-major
        let c0 = vecf(&mut rng, (m - 1) * ldc + n, 0.5);
        let tag = format!("sweep {sweep}: m={m} k={k} n={n}");

        // the pinned reordering: c[i,j] += dot_lanes(A_i, Bᵀ_j, lanes)
        let oracle = |bt_row: &dyn Fn(usize) -> Vec<f32>| -> Vec<f32> {
            let mut c = c0.clone();
            for i in 0..m {
                let ar = &a[i * lda..i * lda + k];
                for j in 0..n {
                    c[i * ldc + j] += dot_lanes(ar, &bt_row(j), lane);
                }
            }
            c
        };

        let want = oracle(&|j| bt[j * k..(j + 1) * k].to_vec());
        let mut c = c0.clone();
        dx.matmul_bt_acc_strided(&a, lda, &bt, m, k, n, &mut c, ldc);
        assert_eq!(c, want, "bt strided: {tag}");

        // loop-tiling over output columns must not touch k-accumulation
        let tile = rng.range(1, n as i64 + 3) as usize;
        let mut c = c0.clone();
        dx.matmul_bt_acc_tiled(&a, lda, &bt, tile, m, k, n, &mut c, ldc);
        assert_eq!(c, want, "bt tiled tile={tile}: {tag}");

        // bf16 Bᵀ: widening is exact, so the oracle is the same dot
        // over the widened rows
        let bth = to_bf16(&bt);
        let want = oracle(&|j| {
            bth[j * k..(j + 1) * k].iter().map(|&h| bf16_to_f32(h))
                .collect()
        });
        let mut c = c0.clone();
        dx.matmul_bt_acc_strided_bf16(&a, lda, &bth, m, k, n, &mut c,
                                      ldc);
        assert_eq!(c, want, "bt bf16: {tag}");

        // and the bare dot kernel is the oracle at every ragged k
        let x = &a[..k];
        let y = &bt[..k];
        assert_eq!(dx.dot(x, y), dot_lanes(x, y, lane), "dot: {tag}");
    }
}

#[test]
fn row_kernels_match_the_reduction_and_polynomial_oracles() {
    let dx = Dispatch::new(Isa::detect());
    let lane = lanes(dx.isa);
    let vector = lane > 1;
    let mut rng = Rng::new(0x5EED_0004);
    let eps = 1e-5f32;
    for len in 1..=40 {
        // rmsnorm: lane-folded variance, then elementwise scale —
        // reproducible exactly from sum_sq_lanes
        let x0 = vecf(&mut rng, len, 1.2);
        let w = vecf(&mut rng, len, 1.0);
        let mut want = x0.clone();
        let ss = sum_sq_lanes(&want, lane);
        let scale = 1.0 / (ss / len as f32 + eps).sqrt();
        for (v, wv) in want.iter_mut().zip(&w) {
            *v = *v * scale * wv;
        }
        let mut got = x0.clone();
        dx.rmsnorm_row(&mut got, &w, eps);
        assert_eq!(got, want, "rmsnorm len={len}");

        // silu rows: a silu_poly map on vector tiers (tails included),
        // libm silu on scalar
        let mapf: fn(f32) -> f32 = if vector { silu_poly } else { silu };
        let mut got = x0.clone();
        dx.silu_rows(&mut got);
        let want: Vec<f32> = x0.iter().map(|&v| mapf(v)).collect();
        assert_eq!(got, want, "silu_rows len={len}");

        let z = vecf(&mut rng, len, 1.0);
        let mut got = x0.clone();
        dx.silu_gate_rows(&mut got, &z);
        let want: Vec<f32> = x0.iter().zip(&z)
            .map(|(&v, &zv)| v * mapf(zv)).collect();
        assert_eq!(got, want, "silu_gate_rows len={len}");
    }
}

#[test]
fn requesting_every_tier_never_crashes_and_unavailable_falls_back() {
    // Dispatch::new is total: on any host, any requested tier yields a
    // runnable dispatch (unavailable → scalar), so a plan built on one
    // machine executes on another
    let mut rng = Rng::new(0x5EED_0005);
    let a = vecf(&mut rng, 12, 1.0);
    let b = vecf(&mut rng, 12, 1.0);
    for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
        let dx = Dispatch::new(isa);
        assert!(dx.isa.available());
        if !isa.available() {
            assert_eq!(dx.isa, Isa::Scalar, "{isa:?} must fall back");
        }
        let d = dx.dot(&a, &b);
        assert_eq!(d, dot_lanes(&a, &b, lanes(dx.isa)));
    }
}
