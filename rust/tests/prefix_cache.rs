//! Prompt-prefix cache suite (ISSUE 6, DESIGN.md §9).
//!
//! Three layers of pinning:
//!
//!   * a seeded property sweep drives `PrefixCache` with hundreds of
//!     overlapping prompts and checks every lookup against a
//!     brute-force "longest cached chunk-aligned proper prefix"
//!     reference, including LRU/byte-budget accounting invariants,
//!   * eviction order under a byte budget at integration granularity
//!     (real tiny-config `CacheState` payloads),
//!   * the engine-level contract: a repeated shared-prefix prompt takes
//!     the cache-hit path — metrics show the hit and a smaller
//!     `prefill_tokens` delta (only the unshared tail is computed) —
//!     while greedy output stays bitwise identical to a cold-prefill
//!     engine. Session save/resume through `EngineHandle` rides the
//!     same harness.

use mamba2_serve::coordinator::{Engine, EngineConfig, GenerateParams,
                                PrefixCache};
use mamba2_serve::runtime::{sim_config, Backend, CacheState,
                            ReferenceBackend};
use mamba2_serve::util::prng::Rng;

const CHUNK: usize = 16;

fn stamped(v: f32) -> CacheState {
    let cfg = sim_config("tiny").unwrap();
    let mut c = CacheState::zeros(&cfg, 1);
    c.ssm.data[0..4].copy_from_slice(&v.to_le_bytes());
    c
}

fn marker(c: &CacheState) -> f32 {
    f32::from_le_bytes(c.ssm.data[0..4].try_into().unwrap())
}

#[test]
fn lookup_matches_brute_force_reference() {
    let mut pc = PrefixCache::new(1 << 30, CHUNK); // no eviction pressure
    let mut rng = Rng::new(0xC0FFEE);
    // inserted keys with their marker values, in insertion order
    let mut model: Vec<(Vec<i32>, f32)> = Vec::new();
    let mut lookups = 0u64;
    let mut want_hits = 0u64;
    for step in 0..400 {
        // prompts share structure: half the time extend a known key so
        // prefix overlaps are dense; tokens from a tiny alphabet so
        // accidental overlaps happen too
        let mut p: Vec<i32> = if !model.is_empty() && rng.below(2) == 0 {
            let i = rng.below(model.len() as u64) as usize;
            model[i].0.clone()
        } else {
            Vec::new()
        };
        for _ in 0..rng.range(1, 40) {
            p.push(rng.range(0, 3) as i32);
        }
        // brute-force reference: longest cached chunk-aligned proper
        // prefix (latest marker wins for a re-inserted key)
        let max_aligned = (p.len() - 1) / CHUNK * CHUNK;
        let mut want: Option<(usize, f32)> = None;
        for (k, m) in &model {
            if k.len() <= max_aligned && p.starts_with(k) {
                match want {
                    Some((n, _)) if n > k.len() => {}
                    _ => want = Some((k.len(), *m)),
                }
            }
        }
        lookups += 1;
        want_hits += want.is_some() as u64;
        match (pc.lookup(&p), want) {
            (None, None) => {}
            (Some((c, n)), Some((wn, wm))) => {
                assert_eq!(n, wn, "step {step}: prefix length");
                assert_eq!(marker(&c), wm, "step {step}: wrong entry");
            }
            (got, want) => panic!(
                "step {step}: lookup {:?} but reference {:?}",
                got.map(|(_, n)| n), want.map(|(n, _)| n)),
        }
        // sometimes insert an aligned prefix of this prompt
        if max_aligned >= CHUNK && rng.below(2) == 0 {
            let lens = max_aligned / CHUNK;
            let klen = (rng.below(lens as u64) as usize + 1) * CHUNK;
            let m = step as f32;
            pc.insert(&p[..klen], &stamped(m));
            // mirror into the reference model (replace same key)
            model.retain(|(k, _)| k[..] != p[..klen]);
            model.push((p[..klen].to_vec(), m));
        }
    }
    let s = pc.stats();
    assert_eq!(s.hits + s.misses, lookups, "every lookup counted once");
    assert_eq!(s.hits, want_hits, "hit count matches the reference");
    assert_eq!(s.entries as usize, model.len());
    assert_eq!(s.evictions, 0, "budget was never exceeded");
    assert!(s.entries > 20, "sweep too shallow to mean anything");
}

#[test]
fn byte_budget_eviction_orders_by_recency() {
    let key = |base: i32| -> Vec<i32> {
        (0..CHUNK as i32).map(|i| base + i).collect()
    };
    let one = stamped(0.0).nbytes() + CHUNK * 4;
    let mut pc = PrefixCache::new(3 * one + 32, CHUNK);
    for (i, base) in [0, 100, 200].iter().enumerate() {
        pc.insert(&key(*base), &stamped(i as f32));
    }
    assert_eq!(pc.stats().entries, 3);
    // touch 0 and 200; 100 becomes LRU
    let probe = |mut k: Vec<i32>| { k.push(7); k };
    assert!(pc.lookup(&probe(key(0))).is_some());
    assert!(pc.lookup(&probe(key(200))).is_some());
    pc.insert(&key(300), &stamped(3.0));
    let s = pc.stats();
    assert_eq!(s.evictions, 1);
    assert_eq!(s.entries, 3);
    assert!(s.bytes as usize <= 3 * one + 32, "budget holds");
    assert!(pc.lookup(&probe(key(100))).is_none(), "LRU evicted");
    for base in [0, 200, 300] {
        assert!(pc.lookup(&probe(key(base))).is_some(),
                "recent entry {base} survives");
    }
}

// ------------------------------------------------- engine-level ---

fn engine(prefix_cache_bytes: usize) -> mamba2_serve::coordinator::EngineHandle {
    let backend: Box<dyn Backend> =
        Box::new(ReferenceBackend::seeded("tiny", 0).unwrap());
    Engine::start(backend, EngineConfig {
        prefix_cache_bytes,
        ..Default::default()
    }).unwrap()
}

fn prompt(len: usize, salt: usize) -> Vec<i32> {
    (0..len).map(|i| ((i * 37 + 11 * salt + 3) % 512) as i32).collect()
}

#[test]
fn shared_prefix_hit_skips_reprefill_and_stays_bitwise() {
    // two prompts sharing a 64-token (chunk-aligned) system prompt
    let shared = prompt(64, 1);
    let mut p1 = shared.clone();
    p1.extend(prompt(9, 2));
    let mut p2 = shared.clone();
    p2.extend(prompt(9, 3));
    let params = || GenerateParams::new().max_new_tokens(10);

    // cold reference: cache disabled, every prompt fully prefilled
    let cold = engine(0);
    let want1 = cold.generate(p1.clone(), params()).collect().unwrap();
    let want2 = cold.generate(p2.clone(), params()).collect().unwrap();
    let cs = cold.metrics.snapshot();
    assert_eq!(cs.prefill_tokens, (p1.len() + p2.len()) as u64);
    assert_eq!((cs.prefix_hits, cs.prefix_insertions), (0, 0),
               "budget 0 disables the cache");
    assert_eq!(cs.prefix_misses, 2, "misses still counted when disabled");

    // warm engine: p1 populates the cache, p2 hits it
    let warm = engine(16 << 20);
    let got1 = warm.generate(p1.clone(), params()).collect().unwrap();
    let s1 = warm.metrics.snapshot();
    assert_eq!(got1, want1, "cold/warm greedy outputs diverged (p1)");
    assert_eq!(s1.prefix_hits, 0, "nothing to hit yet");
    assert_eq!(s1.prefix_insertions, 1, "p1's 64-token prefix cached");
    assert_eq!(s1.prefill_tokens, p1.len() as u64);

    let got2 = warm.generate(p2.clone(), params()).collect().unwrap();
    let s2 = warm.metrics.snapshot();
    assert_eq!(got2, want2, "cache-hit generation must be bitwise \
                             identical to cold prefill");
    assert_eq!(s2.prefix_hits, 1, "p2 hit the shared prefix");
    // the satellite-4 pin: the shared 64 tokens were NOT re-prefilled —
    // only p2's 9-token tail was computed
    assert_eq!(s2.prefill_tokens - s1.prefill_tokens,
               (p2.len() - shared.len()) as u64,
               "hit prompts must not re-run the shared segment");
    assert_eq!(s2.prefix_entries, 1, "no duplicate entry for p2");
    assert!(s2.prefix_bytes > 0);

    // an identical re-submission hits the same entry again
    let got3 = warm.generate(p1.clone(), params()).collect().unwrap();
    let s3 = warm.metrics.snapshot();
    assert_eq!(got3, want1, "repeat prompt diverged");
    assert_eq!(s3.prefix_hits, 2);
    assert_eq!(s3.prefill_tokens - s2.prefill_tokens,
               (p1.len() - shared.len()) as u64);
}

#[test]
fn multi_turn_chat_reuses_growing_prefix() {
    // turn k's prompt extends turn k-1's — the multi-turn pattern the
    // cache exists for; each turn only prefills its new suffix (plus
    // the sub-chunk remainder of the previous turn)
    let cold = engine(0);
    let warm = engine(16 << 20);
    let mut convo = prompt(48, 5);
    let mut last_prefill = 0u64;
    for turn in 0..3 {
        let params = GenerateParams::new().max_new_tokens(6);
        let want = cold.generate(convo.clone(), params.clone())
            .collect().unwrap();
        let got = warm.generate(convo.clone(), params).collect().unwrap();
        assert_eq!(got, want, "turn {turn} diverged");
        let s = warm.metrics.snapshot();
        let turn_prefill = s.prefill_tokens - last_prefill;
        last_prefill = s.prefill_tokens;
        if turn > 0 {
            assert!(s.prefix_hits >= turn as u64, "turn {turn}: no hit");
            // never recompute more than the new suffix + one chunk
            assert!(turn_prefill <= (30 + CHUNK) as u64,
                    "turn {turn} prefilled {turn_prefill} tokens");
        }
        // extend the conversation with the reply + the next user turn
        convo.extend(&want);
        convo.extend(prompt(24, 7 + turn));
    }
}

#[test]
fn engine_session_save_resume_matches_uninterrupted() {
    let eng = engine(16 << 20);
    let p = prompt(73, 9);
    let params = || GenerateParams::new().max_new_tokens(12);
    let want = eng.generate(p.clone(), params()).collect().unwrap();

    // save at the full prompt, resume with an empty continuation: the
    // stored last-logits row must reproduce the stream bitwise
    let state = eng.session_save(p.clone()).unwrap();
    assert_eq!(state.position, p.len() as u64);
    let got = eng.session_resume(state, Vec::new(), params())
        .collect().unwrap();
    assert_eq!(got, want, "resumed stream diverged");

    // save at a chunk-aligned cut, resume with the rest of the prompt
    let state = eng.session_save(p[..64].to_vec()).unwrap();
    let got = eng.session_resume(state, p[64..].to_vec(), params())
        .collect().unwrap();
    assert_eq!(got, want, "mid-prompt resume diverged");

    // wrong-config blob is rejected up front: the stream fails, the
    // engine keeps serving
    let other = ReferenceBackend::seeded("sim-130m", 0).unwrap();
    let (cache, last) = other.prefill_any(&p[..16]).unwrap();
    let alien = other.snapshot(&cache, 0, 16, &last).unwrap();
    let err = eng.session_resume(alien, Vec::new(), params()).collect();
    assert!(err.is_err(), "alien-config resume must fail");
    let again = eng.generate(p.clone(), params()).collect().unwrap();
    assert_eq!(again, want, "engine must survive a bad resume");
}
