//! Bitwise resumability suite (ISSUE 6, DESIGN.md §9).
//!
//! The O(1)-state claim made operational: freezing a sequence into a
//! [`SessionState`], serialising it, and restoring it — on the same
//! backend or a freshly constructed one — must not move a single bit of
//! the subsequent generation. Each comparison pairs identical op
//! sequences, which is what the snapshot design guarantees:
//!
//!   * a chunk-aligned snapshot resumes through `prefill_continue`, on
//!     the same chunk grid as the uninterrupted prefill (the PR 3
//!     segmentation invariant),
//!   * a mid-chunk snapshot (e.g. taken mid-decode) replays its tail
//!     through the O(1) decode step — exactly the ops the uninterrupted
//!     stream would have run,
//!   * an empty continuation samples from the stored `last_logits` row.
//!
//! The sweep covers plan on/off × threads 1/4 × f32/bf16 weights ×
//! ragged and chunk-aligned prompts, plus batch-4 slot extraction and
//! mid-decode snapshot points. The byte format's negative space rides
//! here too: truncated, bit-flipped, wrong-version, wrong-magic and
//! wrong-config blobs must error cleanly, never panic.

use mamba2_serve::runtime::{argmax_last, fnv1a64, Backend, CacheState,
                            PlanMode, ReferenceBackend, SessionState,
                            WeightsDtype, SESSION_VERSION};
use mamba2_serve::tensor::Tensor;

fn backend(plan: PlanMode, threads: usize, w: WeightsDtype)
    -> ReferenceBackend {
    ReferenceBackend::seeded("tiny", 0).unwrap()
        .with_threads(threads)
        .with_plan_mode(plan)
        .with_weights_dtype(w)
}

fn prompt(len: usize, salt: usize) -> Vec<i32> {
    (0..len).map(|i| ((i * 37 + 11 * salt + 3) % 512) as i32).collect()
}

/// Greedy decode `n` steps starting by feeding `first`; returns the
/// sampled tokens, every step's logits row, and the final cache.
fn greedy(b: &ReferenceBackend, cache: &CacheState, first: i32, n: usize)
    -> (Vec<i32>, Vec<Vec<f32>>, CacheState) {
    let mut cache = cache.clone();
    let mut tok = first;
    let mut toks = Vec::new();
    let mut rows = Vec::new();
    for _ in 0..n {
        let out = b.decode_step(&cache, &[tok]).unwrap();
        tok = argmax_last(&out.logits)[0];
        toks.push(tok);
        rows.push(out.logits.as_f32());
        cache = out.cache;
    }
    (toks, rows, cache)
}

#[test]
fn snapshot_restore_decode_bitwise_sweep() {
    for &plan in &[PlanMode::On, PlanMode::Off] {
        for &threads in &[1usize, 4] {
            for &w in &[WeightsDtype::F32, WeightsDtype::Bf16] {
                // 96 = chunk-aligned (6×16); 100 exercises the
                // sub-bucket decode tail of prefill_any
                for &plen in &[96usize, 100] {
                    let tag = format!("plan={plan:?} threads={threads} \
                                       w={w:?} plen={plen}");
                    let saver = backend(plan, threads, w);
                    let p = prompt(plen, 1);
                    let (cache, last) = saver.prefill_any(&p).unwrap();
                    let first = argmax_last(&last)[0];
                    let (want_toks, want_rows, _) =
                        greedy(&saver, &cache, first, 12);

                    let snap = saver
                        .snapshot(&cache, 0, plen as u64, &last)
                        .unwrap();
                    // round-trip through the wire format
                    let blob = snap.to_bytes();
                    assert_eq!(blob.len(), snap.nbytes(), "{tag}: nbytes");
                    let rt = SessionState::from_bytes(&blob).unwrap();
                    assert_eq!(rt.position, plen as u64, "{tag}");
                    assert_eq!(rt.config, "tiny", "{tag}");
                    assert_eq!(rt.last_logits.as_f32(), last.as_f32(),
                               "{tag}: stored logits row");
                    // the empty-continuation contract: the stored row
                    // samples the next token the stream would produce
                    assert_eq!(argmax_last(&rt.last_logits)[0], first,
                               "{tag}: resume-with-no-tokens token");

                    // restore on the saving instance AND a fresh one
                    let fresh = backend(plan, threads, w);
                    for (who, b) in [("same", &saver), ("fresh", &fresh)] {
                        let rc = b.restore(&rt).unwrap();
                        assert_eq!(rc.ssm.as_f32(), cache.ssm.as_f32(),
                                   "{tag} {who}: ssm");
                        assert_eq!(rc.conv.as_f32(), cache.conv.as_f32(),
                                   "{tag} {who}: conv");
                        let (toks, rows, _) = greedy(b, &rc, first, 12);
                        assert_eq!(toks, want_toks, "{tag} {who}: tokens");
                        assert_eq!(rows, want_rows, "{tag} {who}: logits");
                    }
                }
            }
        }
    }
}

#[test]
fn mid_decode_snapshot_resumes_bitwise() {
    let b = backend(PlanMode::On, 4, WeightsDtype::F32);
    let fresh = backend(PlanMode::On, 4, WeightsDtype::F32);
    let p = prompt(40, 3);
    let (cache, last) = b.prefill_any(&p).unwrap();
    let first = argmax_last(&last)[0];
    let (toks, rows, _) = greedy(&b, &cache, first, 20);
    // snapshot after k decode steps — positions 41/45/51, none of them
    // chunk-aligned, so the resume MUST take the decode-replay path
    for &k in &[1usize, 5, 11] {
        let (_, krows, kcache) = greedy(&b, &cache, first, k);
        let last_row = Tensor::f32(
            "last", &[1, b.cfg().vocab_size as i64], &krows[k - 1]);
        let snap = b
            .snapshot(&kcache, 0, (p.len() + k) as u64, &last_row)
            .unwrap();
        let rt = SessionState::from_bytes(&snap.to_bytes()).unwrap();
        let rc = fresh.restore(&rt).unwrap();
        // the token the interrupted stream was about to feed
        assert_eq!(argmax_last(&rt.last_logits)[0], toks[k - 1], "k={k}");
        let (ctoks, crows, _) = greedy(&fresh, &rc, toks[k - 1], 20 - k);
        assert_eq!(ctoks, toks[k..], "k={k}: tokens");
        assert_eq!(crows, rows[k..], "k={k}: logits");
    }
}

#[test]
fn mid_chunk_seeded_continuation_replays_decode_path() {
    // position 40 is mid-chunk (40 % 16 != 0): prefill_any_seeded may
    // not re-enter the chunked path, and must instead replay the
    // continuation through decode_step — the same ops a teacher-forced
    // uninterrupted stream runs
    let b = backend(PlanMode::On, 4, WeightsDtype::F32);
    let p = prompt(64, 5);
    let (cache, last) = b.prefill_any(&p[..40]).unwrap();
    // uninterrupted: teacher-force the remaining prompt through decode
    let mut want_cache = cache.clone();
    let mut want_last = last.clone();
    for i in 40..64 {
        let out = b.decode_step(&want_cache, &p[i..=i]).unwrap();
        want_cache = out.cache;
        want_last = out.logits;
    }
    // interrupted: snapshot at 40, restore, seed the tail prefill
    let snap = b.snapshot(&cache, 0, 40, &last).unwrap();
    let rt = SessionState::from_bytes(&snap.to_bytes()).unwrap();
    let rc = b.restore(&rt).unwrap();
    let (got_cache, got_last) = b
        .prefill_any_seeded(&p[40..], Some((&rc, rt.position as usize)))
        .unwrap();
    assert_eq!(got_last.as_f32(), want_last.as_f32(), "logits");
    assert_eq!(got_cache.ssm.as_f32(), want_cache.ssm.as_f32(), "ssm");
    assert_eq!(got_cache.conv.as_f32(), want_cache.conv.as_f32(), "conv");
}

#[test]
fn chunk_aligned_seeded_continuation_matches_joint_prefill() {
    // snapshot at 64 (chunk- and bucket-aligned): the seeded
    // continuation re-enters the chunked bucket chain on the SAME
    // chunk grid as the joint prefill — 64 | 16 | 16 | 16 either way —
    // so the PR 3 segmentation invariant makes it bitwise
    let b = backend(PlanMode::On, 4, WeightsDtype::F32);
    let p = prompt(112, 7);
    let (want_cache, want_last) = b.prefill_any(&p).unwrap();
    let (head_cache, head_last) = b.prefill_any(&p[..64]).unwrap();
    let snap = b.snapshot(&head_cache, 0, 64, &head_last).unwrap();
    let rt = SessionState::from_bytes(&snap.to_bytes()).unwrap();
    let rc = b.restore(&rt).unwrap();
    let (got_cache, got_last) = b
        .prefill_any_seeded(&p[64..], Some((&rc, 64)))
        .unwrap();
    assert_eq!(got_last.as_f32(), want_last.as_f32(), "logits");
    assert_eq!(got_cache.ssm.as_f32(), want_cache.ssm.as_f32(), "ssm");
    assert_eq!(got_cache.conv.as_f32(), want_cache.conv.as_f32(), "conv");
}

#[test]
fn batched_slots_snapshot_and_resume_independently() {
    // slots never mix (the decode contract), so freezing slot s out of
    // a batch-4 decode and resuming it at batch 1 must continue slot
    // s's stream bitwise
    let b = backend(PlanMode::On, 4, WeightsDtype::F32);
    let fresh = backend(PlanMode::On, 4, WeightsDtype::F32);
    let bsz = 4usize;
    let v = b.cfg().vocab_size;
    let mut cache = CacheState::zeros(b.cfg(), bsz);
    let mut toks = vec![0i32; bsz];
    let mut consumed = vec![0u64; bsz];
    for s in 0..bsz {
        let p = prompt(16 + 8 * s, s + 1);
        consumed[s] = p.len() as u64;
        let (c1, l1) = b.prefill_any(&p).unwrap();
        cache.copy_slot_from(s, &c1, 0);
        toks[s] = argmax_last(&l1)[0];
    }
    // a few batched greedy steps, keeping each slot's last logits row
    let mut last_rows = vec![Vec::new(); bsz];
    for _ in 0..4 {
        let out = b.decode_step(&cache, &toks).unwrap();
        let lv = out.logits.as_f32();
        for s in 0..bsz {
            last_rows[s] = lv[s * v..(s + 1) * v].to_vec();
            consumed[s] += 1;
        }
        toks = argmax_last(&out.logits);
        cache = out.cache;
    }
    // uninterrupted continuation: 6 more batched steps
    let mut want = vec![Vec::new(); bsz];
    {
        let mut c = cache.clone();
        let mut t = toks.clone();
        for _ in 0..6 {
            let out = b.decode_step(&c, &t).unwrap();
            t = argmax_last(&out.logits);
            for s in 0..bsz {
                want[s].push(t[s]);
            }
            c = out.cache;
        }
    }
    // freeze each slot, round-trip, resume at batch 1 on a fresh
    // instance
    for s in 0..bsz {
        let row = Tensor::f32("last", &[1, v as i64], &last_rows[s]);
        let snap = b.snapshot(&cache, s, consumed[s], &row).unwrap();
        let rt = SessionState::from_bytes(&snap.to_bytes()).unwrap();
        let rc = fresh.restore(&rt).unwrap();
        assert_eq!(argmax_last(&rt.last_logits)[0], toks[s], "slot {s}");
        let (got, _, _) = greedy(&fresh, &rc, toks[s], 6);
        assert_eq!(got, want[s], "slot {s}: resumed tokens");
    }
}

// ------------------------------------------------- malformed blobs ---

fn saved_blob() -> (ReferenceBackend, Vec<u8>) {
    let b = backend(PlanMode::On, 1, WeightsDtype::F32);
    let p = prompt(32, 2);
    let (cache, last) = b.prefill_any(&p).unwrap();
    let blob = b.snapshot(&cache, 0, 32, &last).unwrap().to_bytes();
    (b, blob)
}

#[test]
fn truncated_blobs_error_cleanly() {
    let (_, blob) = saved_blob();
    let n = blob.len();
    // every structurally interesting cut: inside magic, version,
    // fingerprint, name, each tensor header/payload, and the checksum
    let cuts = [0, 1, 3, 7, 8, 11, 15, 23, 24, 27, 28, 40, n / 3, n / 2,
                n - 9, n - 8, n - 1];
    for &cut in &cuts {
        let e = SessionState::from_bytes(&blob[..cut]);
        assert!(e.is_err(), "cut at {cut} of {n} must error");
    }
}

#[test]
fn bit_flips_error_cleanly_everywhere() {
    let (_, blob) = saved_blob();
    // a flip anywhere — header, dims, payload, checksum — must be
    // caught (magic/version checks or the FNV checksum); sample the
    // whole blob at a stride that still covers every region
    let stride = blob.len() / 97 + 1;
    for i in (0..blob.len()).step_by(stride) {
        let mut bad = blob.clone();
        bad[i] ^= 1 << (i % 8);
        assert!(SessionState::from_bytes(&bad).is_err(), "flip at {i}");
    }
}

#[test]
fn wrong_version_and_magic_are_named_errors() {
    let (_, blob) = saved_blob();
    // future version, checksum re-stamped so the version check (not
    // the checksum) is what fires
    let mut v99 = blob.clone();
    v99[4..8].copy_from_slice(&(SESSION_VERSION + 98).to_le_bytes());
    let n = v99.len();
    let ck = fnv1a64(&v99[..n - 8]);
    v99[n - 8..].copy_from_slice(&ck.to_le_bytes());
    let e = SessionState::from_bytes(&v99).unwrap_err().to_string();
    assert!(e.contains("version 99"), "got: {e}");

    let mut bad_magic = blob.clone();
    bad_magic[0] ^= 0xff;
    let e = SessionState::from_bytes(&bad_magic).unwrap_err().to_string();
    assert!(e.contains("magic"), "got: {e}");

    let mut flipped = blob;
    flipped[20] ^= 0x10;
    let e = SessionState::from_bytes(&flipped).unwrap_err().to_string();
    assert!(e.contains("checksum"), "got: {e}");
}

#[test]
fn wrong_config_restore_is_rejected() {
    let (_, blob) = saved_blob();
    let rt = SessionState::from_bytes(&blob).unwrap();
    let other = ReferenceBackend::seeded("sim-130m", 0).unwrap();
    let e = other.restore(&rt).unwrap_err().to_string();
    assert!(e.contains("tiny") && e.contains("sim-130m"), "got: {e}");
}

#[test]
fn snapshot_rejects_bad_slot_and_logits() {
    let b = backend(PlanMode::Off, 1, WeightsDtype::F32);
    let (cache, last) = b.prefill_any(&prompt(16, 1)).unwrap();
    assert!(b.snapshot(&cache, 1, 16, &last).is_err(), "slot 1 of 1");
    let narrow = Tensor::f32("last", &[1, 7], &[0.0; 7]);
    assert!(b.snapshot(&cache, 0, 16, &narrow).is_err(), "narrow logits");
}
