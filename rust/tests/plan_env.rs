//! M2_PLAN environment selection, isolated in its own test binary.
//!
//! This file must contain exactly ONE test: `std::env::set_var` is not
//! thread-safe against the `env::var` reads other tests perform
//! (concurrent setenv/getenv is UB on glibc), and cargo runs all tests
//! of one binary in parallel threads. A single test in a dedicated
//! binary serialises by construction.

use mamba2_serve::runtime::{Backend, PlanMode, ReferenceBackend};

#[test]
fn plan_mode_env_is_honoured() {
    // M2_PLAN=off must select the hand-scheduled oracle at construction
    // time (this is what `--plan off` on the binaries sets)
    std::env::set_var("M2_PLAN", "off");
    let b = ReferenceBackend::seeded("tiny", 0).unwrap();
    assert_eq!(b.plan_mode(), PlanMode::Off);
    assert!(b.plan_stats().is_none());
    assert!(b.plan_dump("prefill", 16, 1).is_none());

    std::env::set_var("M2_PLAN", "on");
    let c = ReferenceBackend::seeded("tiny", 0).unwrap();
    assert_eq!(c.plan_mode(), PlanMode::On);

    std::env::remove_var("M2_PLAN");
    let d = ReferenceBackend::seeded("tiny", 0).unwrap();
    assert_eq!(d.plan_mode(), PlanMode::On, "planned is the default");
}
