//! Integration: the HTTP gateway end-to-end (bind :0, real sockets),
//! hermetically on the pure-Rust reference backend. Pins the PR's
//! acceptance surface:
//!
//!   * HTTP completions (blocking and SSE) produce bitwise-identical
//!     token ids to the framed wire protocol over the SAME replica
//!     pool and tokenizer,
//!   * malformed HTTP gets a 4xx without killing the listener,
//!   * a mid-stream client disconnect cancels the engine side and
//!     frees the decode slot,
//!   * admission control sheds with `429` + `Retry-After` while
//!     admitted work completes, and `/metrics` exposes the shed
//!     counter in valid Prometheus exposition format,
//!   * graceful drain finishes in-flight streams before the listener
//!     goes away,
//!   * both frontends read ONE in-flight number (the shared gauge) and
//!     ONE connection-error breakdown.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{mpsc, Arc, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

use mamba2_serve::coordinator::{ConnErrors, GenerateParams, Router};
use mamba2_serve::eval::{corpus, Tokenizer};
use mamba2_serve::gateway::http::http_roundtrip;
use mamba2_serve::gateway::pool::{self, PoolConfig};
use mamba2_serve::gateway::prom::validate_exposition;
use mamba2_serve::gateway::{sse, Gateway, GatewayConfig, GatewayHandle};
use mamba2_serve::server::{Client, Frame, Server};
use mamba2_serve::util::json::Json;

/// One full serving stack: a replica pool with BOTH frontends on it —
/// the HTTP gateway and the wire server share the router, tokenizer,
/// in-flight gauge, and connection-error counters, exactly as `main`
/// wires them.
struct Stack {
    http: SocketAddr,
    wire: String,
    router: Arc<Router>,
    handle: Option<GatewayHandle>,
}

fn build_stack(replicas: usize, batch_cap: usize,
               max_queue_depth: usize, keep_alive_ms: u64) -> Stack {
    let (router, _gauge) = pool::build(PoolConfig {
        model: "tiny".into(),
        backend: "reference".into(),
        replicas,
        batch_cap,
        ..Default::default()
    }).unwrap();
    let tok = Arc::new(Tokenizer::train(corpus::BUNDLED, 64));
    let errs = Arc::new(ConnErrors::new());
    let gw = Gateway::with_conn_errors(
        Arc::clone(&router), Arc::clone(&tok),
        GatewayConfig {
            model: "tiny".into(),
            threads: 4,
            max_queue_depth,
            keep_alive: Duration::from_millis(keep_alive_ms),
        },
        Arc::clone(&errs));
    let h = gw.start("127.0.0.1:0").unwrap();
    let http = h.addr();
    let (tx, rx) = mpsc::channel();
    let (r2, t2) = (Arc::clone(&router), Arc::clone(&tok));
    thread::spawn(move || {
        Server::new(r2, t2).with_conn_errors(errs)
            .serve("127.0.0.1:0", 4, move |a| {
                tx.send(a.to_string()).unwrap();
            }).unwrap();
    });
    let wire = rx.recv_timeout(Duration::from_secs(30))
        .expect("wire server bound");
    Stack { http, wire, router, handle: Some(h) }
}

/// Shared stack (2 replicas — the seeded reference replicas are
/// identical, so parity holds whichever one the router picks).
fn fx() -> &'static Stack {
    static S: OnceLock<Stack> = OnceLock::new();
    S.get_or_init(|| build_stack(2, 4, 64, 2000))
}

fn post(addr: &SocketAddr, body: &str)
    -> (u16, Vec<(String, String)>, Json) {
    let (status, headers, raw) =
        http_roundtrip(addr, "POST", "/v1/completions", body.as_bytes())
            .unwrap();
    let j = Json::parse(std::str::from_utf8(&raw).unwrap()).unwrap();
    (status, headers, j)
}

fn token_ids(choice: &Json) -> Vec<i64> {
    choice.get("token_ids").and_then(Json::as_arr).unwrap()
        .iter().map(|t| t.as_i64().unwrap()).collect()
}

fn metric_value(exposition: &str, prefix: &str) -> f64 {
    exposition.lines()
        .find(|l| l.starts_with(prefix))
        .unwrap_or_else(|| panic!("no sample starting {prefix:?}"))
        .rsplit(' ').next().unwrap().parse().unwrap()
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < Duration::from_secs(30),
                "timed out waiting for {what}");
        thread::sleep(Duration::from_millis(5));
    }
}

// ----------------------------------------------------- basic routes ---

#[test]
fn healthz_models_and_unknown_routes() {
    let s = fx();
    let (st, _, body) =
        http_roundtrip(&s.http, "GET", "/healthz", b"").unwrap();
    assert_eq!(st, 200);
    assert_eq!(body, b"ok");
    let (st, _, body) =
        http_roundtrip(&s.http, "GET", "/v1/models", b"").unwrap();
    assert_eq!(st, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let m = &j.get("data").and_then(Json::as_arr).unwrap()[0];
    assert_eq!(m.get("id").and_then(Json::as_str), Some("tiny"));
    let (st, _, _) =
        http_roundtrip(&s.http, "GET", "/nope", b"").unwrap();
    assert_eq!(st, 404);
}

// ------------------------------------------------ wire/HTTP parity ---

#[test]
fn http_completion_matches_wire_token_ids() {
    let s = fx();
    // v1 wire path: greedy, explicit token budget
    let mut c = Client::connect(&s.wire).unwrap();
    let wire = c.generate("state space duality", 8).unwrap();
    assert!(wire.get("error").is_none(), "{wire}");
    let wire_ids: Vec<i64> = wire.get("tokens").and_then(Json::as_arr)
        .unwrap().iter().map(|t| t.as_i64().unwrap()).collect();
    assert_eq!(wire_ids.len(), 8);
    // HTTP path: same prompt, same budget, no sampling fields (greedy)
    let (st, _, j) = post(&s.http,
        r#"{"model":"tiny","prompt":"state space duality","max_tokens":8}"#);
    assert_eq!(st, 200, "{j}");
    let choice = &j.get("choices").and_then(Json::as_arr).unwrap()[0];
    assert_eq!(token_ids(choice), wire_ids,
               "HTTP and wire token ids diverged");
    assert_eq!(choice.get("text").and_then(Json::as_str),
               wire.get("text").and_then(Json::as_str));
    assert_eq!(choice.get("finish_reason").and_then(Json::as_str),
               Some("length"));
    assert_eq!(j.at(&["usage", "completion_tokens"])
               .and_then(Json::as_u64), Some(8));
}

#[test]
fn sse_stream_matches_wire_stream() {
    let s = fx();
    // wire v2 streaming: collect the per-event deltas + terminal usage
    let mut c = Client::connect(&s.wire).unwrap();
    let params = GenerateParams::new().max_new_tokens(10);
    let mut wire_ids: Vec<i64> = Vec::new();
    let mut wire_text = String::new();
    let mut wire_usage = Json::Null;
    let mut stream = c.generate_stream("compiler first caching", &params)
        .unwrap();
    while let Some(f) = stream.next_frame().unwrap() {
        match f {
            Frame::Delta { tokens, text } => {
                wire_ids.extend(tokens.iter().map(|&t| t as i64));
                wire_text.push_str(&text);
            }
            Frame::Done { finish_reason, usage } => {
                assert_eq!(finish_reason, "length");
                wire_usage = usage;
            }
            Frame::Error(e) => panic!("wire stream error: {e}"),
        }
    }
    // HTTP SSE: same prompt/budget; Connection: close makes read-to-EOF
    // return the full event stream
    let (st, _, raw) = http_roundtrip(
        &s.http, "POST", "/v1/completions",
        br#"{"model":"tiny","prompt":"compiler first caching","max_tokens":10,"stream":true}"#)
        .unwrap();
    assert_eq!(st, 200);
    let events = sse::decode(std::str::from_utf8(&raw).unwrap());
    assert_eq!(events.last().map(String::as_str), Some("[DONE]"),
               "stream must end with the DONE frame");
    let chunks: Vec<Json> = events[..events.len() - 1].iter()
        .map(|p| Json::parse(p).unwrap()).collect();
    assert!(chunks.len() >= 2, "expected deltas + terminal chunk");
    let mut http_ids: Vec<i64> = Vec::new();
    let mut http_text = String::new();
    for ch in &chunks[..chunks.len() - 1] {
        let choice = &ch.get("choices").and_then(Json::as_arr).unwrap()[0];
        assert!(choice.get("finish_reason").and_then(Json::as_str)
                .is_none(), "delta chunks must not carry a finish");
        http_ids.extend(token_ids(choice));
        http_text.push_str(
            choice.get("text").and_then(Json::as_str).unwrap());
    }
    let last = chunks.last().unwrap();
    let lchoice = &last.get("choices").and_then(Json::as_arr).unwrap()[0];
    assert_eq!(lchoice.get("finish_reason").and_then(Json::as_str),
               Some("length"));
    assert_eq!(http_ids, wire_ids, "SSE and wire deltas diverged");
    assert_eq!(http_text, wire_text);
    assert_eq!(last.at(&["usage", "completion_tokens"])
               .and_then(Json::as_u64),
               wire_usage.get("completion_tokens")
               .and_then(Json::as_u64));
}

// ------------------------------------------------- malformed input ---

#[test]
fn malformed_http_gets_4xx_without_killing_the_listener() {
    let s = fx();
    // wrong method on a known route
    let (st, headers, _) =
        http_roundtrip(&s.http, "DELETE", "/v1/models", b"").unwrap();
    assert_eq!(st, 405);
    assert_eq!(headers.iter().find(|(k, _)| k == "allow")
               .map(|(_, v)| v.as_str()), Some("GET"));
    // bad JSON body
    let (st, _, j) = post(&s.http, "{this is not json");
    assert_eq!(st, 400);
    assert!(j.at(&["error", "message"]).and_then(Json::as_str)
            .unwrap().contains("json"));
    // structurally valid JSON the engine cannot serve
    let (st, _, _) = post(&s.http, r#"{"max_tokens":4}"#);
    assert_eq!(st, 400);
    // unknown model is a 404, not a generation
    let (st, _, _) =
        post(&s.http, r#"{"model":"gpt-99","prompt":"x"}"#);
    assert_eq!(st, 404);
    // truncated body: Content-Length promises more than is sent
    let mut t = TcpStream::connect(s.http).unwrap();
    t.write_all(b"POST /v1/completions HTTP/1.1\r\nHost: t\r\n\
                  Content-Length: 50\r\n\r\nabc").unwrap();
    t.shutdown(std::net::Shutdown::Write).unwrap();
    let mut resp = Vec::new();
    t.read_to_end(&mut resp).unwrap();
    assert!(resp.starts_with(b"HTTP/1.1 400"),
            "{}", String::from_utf8_lossy(&resp));
    // oversized header block
    let mut t = TcpStream::connect(s.http).unwrap();
    let huge = "x".repeat(20 * 1024);
    t.write_all(format!("GET /healthz HTTP/1.1\r\nX-Big: {huge}\r\n\r\n")
                .as_bytes()).unwrap();
    let mut resp = Vec::new();
    t.read_to_end(&mut resp).unwrap();
    assert!(resp.starts_with(b"HTTP/1.1 431"),
            "{}", String::from_utf8_lossy(&resp));
    // the listener survived all of it
    let (st, _, body) =
        http_roundtrip(&s.http, "GET", "/healthz", b"").unwrap();
    assert_eq!((st, body.as_slice()), (200, b"ok".as_slice()));
}

// ------------------------------------------- disconnect mid-stream ---

#[test]
fn mid_stream_disconnect_frees_the_slot() {
    // cap-1 pool: if the vanished client leaked its slot, the follow-up
    // completion could never be admitted
    let mut s = build_stack(1, 1, 64, 2000);
    let h = s.handle.take().unwrap();
    {
        let mut t = TcpStream::connect(s.http).unwrap();
        let body = br#"{"model":"tiny","prompt":"runaway","max_tokens":100000,"stream":true}"#;
        t.write_all(format!(
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\n\
             Content-Length: {}\r\n\r\n", body.len()).as_bytes())
            .unwrap();
        t.write_all(body).unwrap();
        // wait for the stream to actually start, then vanish: dropping
        // the socket with unread data pending makes the next SSE write
        // fail, which must cancel the engine side
        let mut first = [0u8; 16];
        t.read_exact(&mut first).unwrap();
        assert_eq!(&first[..12], b"HTTP/1.1 200");
    }
    wait_until("disconnect cancellation",
               || s.router.total_cancelled() >= 1);
    // the single slot is reusable — this would starve forever if the
    // disconnect had not freed it
    let (st, _, j) = post(&s.http,
        r#"{"model":"tiny","prompt":"after","max_tokens":4}"#);
    assert_eq!(st, 200, "{j}");
    h.drain().unwrap();
}

// -------------------------------------------------- admission control ---

#[test]
fn overload_sheds_429_with_retry_after_while_admitted_work_completes() {
    // one slot, zero queue tolerance: A occupies the slot, B queues,
    // C must be shed
    let mut s = build_stack(1, 1, 0, 2000);
    let h = s.handle.take().unwrap();
    let addr = s.http;
    let long = |tag: usize| {
        thread::spawn(move || {
            let body = format!(
                "{{\"model\":\"tiny\",\"prompt\":\"busy {tag}\",\
                 \"max_tokens\":2048}}");
            http_roundtrip(&addr, "POST", "/v1/completions",
                           body.as_bytes()).unwrap().0
        })
    };
    let a = long(0);
    wait_until("A admitted", || s.router.in_flight() >= 1);
    let b = long(1);
    wait_until("B queued", || s.router.queue_depth() >= 1);
    let (st, headers, j) = post(&addr,
        r#"{"model":"tiny","prompt":"shed me","max_tokens":4}"#);
    assert_eq!(st, 429, "{j}");
    let ra: u64 = headers.iter().find(|(k, _)| k == "retry-after")
        .expect("429 must carry Retry-After").1.parse().unwrap();
    assert!(ra >= 1);
    assert_eq!(j.at(&["error", "type"]).and_then(Json::as_str),
               Some("overloaded"));
    assert_eq!(h.shed_total(), 1);
    // the shed counter is visible in valid Prometheus exposition
    let (st, _, raw) =
        http_roundtrip(&addr, "GET", "/metrics", b"").unwrap();
    assert_eq!(st, 200);
    let text = String::from_utf8(raw).unwrap();
    validate_exposition(&text).unwrap();
    assert_eq!(metric_value(&text, "m2_gateway_shed_total"), 1.0);
    assert!(text.contains("# TYPE m2_gateway_shed_total counter"));
    // the shed 429 already finished its handler, so the per-route
    // latency histogram carries a completions sample (A and B are
    // still streaming and record only once their handlers return)
    assert!(text.contains("# TYPE m2_http_request_seconds histogram"));
    assert!(text.contains(
        "m2_http_request_seconds_bucket{route=\"completions\",le=\"+Inf\"}"));
    assert!(metric_value(
        &text, "m2_http_request_seconds_count{route=\"completions\"}")
        >= 1.0);
    // shedding never touched the admitted requests
    assert_eq!(a.join().unwrap(), 200);
    assert_eq!(b.join().unwrap(), 200);
    h.drain().unwrap();
}

// ----------------------------------------------------- graceful drain ---

#[test]
fn graceful_drain_completes_in_flight_streams() {
    let mut s = build_stack(1, 2, 64, 500);
    let h = s.handle.take().unwrap();
    let addr = s.http;
    let mut t = TcpStream::connect(addr).unwrap();
    let body = br#"{"model":"tiny","prompt":"drain me","max_tokens":64,"stream":true}"#;
    t.write_all(format!(
        "POST /v1/completions HTTP/1.1\r\nHost: t\r\n\
         Content-Length: {}\r\n\r\n", body.len()).as_bytes()).unwrap();
    t.write_all(body).unwrap();
    let mut r = BufReader::new(t);
    let mut status = String::new();
    r.read_line(&mut status).unwrap();
    assert!(status.contains("200"), "{status}");
    // drain with the stream mid-flight
    let drainer = thread::spawn(move || h.drain().unwrap());
    // the admitted stream runs to its DONE frame while draining
    let mut rest = String::new();
    r.read_to_string(&mut rest).unwrap();
    assert!(rest.contains("data: [DONE]"),
            "stream was cut off by drain: ...{}",
            &rest[rest.len().saturating_sub(120)..]);
    drainer.join().unwrap();
    // and afterwards the listener is gone
    assert!(TcpStream::connect(addr).is_err(),
            "listener still accepting after drain");
}

#[test]
fn admin_drain_flips_health_and_refuses_new_work() {
    let mut s = build_stack(1, 2, 64, 2000);
    let h = s.handle.take().unwrap();
    // pre-open keep-alive connections: once drain starts, the accept
    // loop stops, so only existing connections can observe the 503s
    let mut pre1 = RawConn::connect(&s.http);
    let mut pre2 = RawConn::connect(&s.http);
    let (st, body) = pre1.request("GET", "/healthz", b"");
    assert_eq!((st, body.as_slice()), (200, b"ok".as_slice()));
    let (st, _) = RawConn::connect(&s.http)
        .request("POST", "/admin/drain", b"");
    assert_eq!(st, 202);
    let (st, body) = pre1.request("GET", "/healthz", b"");
    assert_eq!(st, 503);
    assert_eq!(body, b"draining");
    let (st, body) = pre2.request(
        "POST", "/v1/completions",
        br#"{"model":"tiny","prompt":"late","max_tokens":2}"#);
    assert_eq!(st, 503, "{}", String::from_utf8_lossy(&body));
    h.drain().unwrap();
}

/// Minimal keep-alive HTTP client: one persistent connection, framed
/// responses via Content-Length (what `http_roundtrip` can't do — it
/// closes per request).
struct RawConn {
    r: BufReader<TcpStream>,
    w: TcpStream,
}

impl RawConn {
    fn connect(addr: &SocketAddr) -> RawConn {
        let w = TcpStream::connect(addr).unwrap();
        RawConn { r: BufReader::new(w.try_clone().unwrap()), w }
    }

    fn request(&mut self, method: &str, path: &str, body: &[u8])
        -> (u16, Vec<u8>) {
        self.w.write_all(format!(
            "{method} {path} HTTP/1.1\r\nHost: t\r\n\
             Content-Length: {}\r\n\r\n", body.len()).as_bytes())
            .unwrap();
        self.w.write_all(body).unwrap();
        self.w.flush().unwrap();
        let mut status_line = String::new();
        self.r.read_line(&mut status_line).unwrap();
        let status: u16 = status_line.split_whitespace().nth(1)
            .unwrap().parse().unwrap();
        let mut len = 0usize;
        loop {
            let mut l = String::new();
            self.r.read_line(&mut l).unwrap();
            let l = l.trim_end();
            if l.is_empty() {
                break;
            }
            if let Some((k, v)) = l.split_once(':') {
                if k.eq_ignore_ascii_case("content-length") {
                    len = v.trim().parse().unwrap();
                }
            }
        }
        let mut body = vec![0u8; len];
        self.r.read_exact(&mut body).unwrap();
        (status, body)
    }
}

// ------------------------------------------- cross-frontend metrics ---

#[test]
fn http_traffic_is_visible_through_the_wire_metrics_op() {
    // dedicated stack: nothing else races the in-flight gauge
    let mut s = build_stack(1, 2, 64, 2000);
    let h = s.handle.take().unwrap();
    let wire_in_flight = |c: &mut Client| {
        c.call(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap()
            .get("in_flight_total").and_then(Json::as_f64).unwrap()
    };
    let mut c = Client::connect(&s.wire).unwrap();
    assert_eq!(wire_in_flight(&mut c), 0.0);
    // park a long-running HTTP stream on the pool...
    let mut t = TcpStream::connect(s.http).unwrap();
    let body = br#"{"model":"tiny","prompt":"park","max_tokens":100000,"stream":true}"#;
    t.write_all(format!(
        "POST /v1/completions HTTP/1.1\r\nHost: t\r\n\
         Content-Length: {}\r\n\r\n", body.len()).as_bytes()).unwrap();
    t.write_all(body).unwrap();
    // ...and the WIRE frontend sees it in flight (the shared gauge)
    wait_until("wire sees HTTP in-flight",
               || wire_in_flight(&mut c) >= 1.0);
    drop(t);
    wait_until("gauge settles after disconnect",
               || wire_in_flight(&mut c) == 0.0);
    // /metrics agrees, and carries the per-kind conn-error breakdown
    // that the wire op also reports
    let (_, _, raw) =
        http_roundtrip(&s.http, "GET", "/metrics", b"").unwrap();
    let text = String::from_utf8(raw).unwrap();
    validate_exposition(&text).unwrap();
    assert_eq!(metric_value(&text, "m2_in_flight_total"), 0.0);
    for kind in ["io", "protocol", "too_large"] {
        assert!(text.contains(
            &format!("m2_conn_errors_total{{kind=\"{kind}\"}}")),
            "missing conn-error kind {kind}");
    }
    let m = c.call(&Json::obj(vec![("op", Json::str("metrics"))]))
        .unwrap();
    let by_kind = m.get("conn_errors_by_kind").expect("wire breakdown");
    for kind in ["io", "protocol", "too_large"] {
        assert!(by_kind.get(kind).and_then(Json::as_f64).is_some());
    }
    h.drain().unwrap();
}

#[test]
fn prefix_cache_hits_identically_over_http() {
    // one replica so the second request lands on the same cache; the
    // prompt must exceed one SSM chunk (tiny: 16 tokens) to be cached
    let mut s = build_stack(1, 4, 64, 2000);
    let h = s.handle.take().unwrap();
    let prompt = "the compiler lowers the state space dual form into a \
                  chunked scan whose carried state is one fixed size \
                  slab per layer and the serving tier snapshots it \
                  between turns of the conversation";
    let body = format!(
        "{{\"model\":\"tiny\",\"prompt\":\"{prompt}\",\"max_tokens\":2}}");
    let (st1, _, j1) = post(&s.http, &body);
    assert_eq!(st1, 200, "{j1}");
    let (st2, _, j2) = post(&s.http, &body);
    assert_eq!(st2, 200, "{j2}");
    // identical prompts through HTTP hash to the same token-id key the
    // wire path uses, so the second request hits the prefix cache
    let (_, _, raw) =
        http_roundtrip(&s.http, "GET", "/metrics", b"").unwrap();
    let text = String::from_utf8(raw).unwrap();
    validate_exposition(&text).unwrap();
    assert!(metric_value(
        &text, "m2_prefix_cache_hits_total{replica=\"0\"}") >= 1.0,
        "no prefix-cache hit over HTTP");
    assert!(metric_value(
        &text, "m2_prefix_cache_misses_total{replica=\"0\"}") >= 1.0);
    assert!(metric_value(
        &text, "m2_prefix_cache_bytes{replica=\"0\"}") > 0.0);
    // the weight-stream identity gauge is exported per replica with a
    // dtype label (f32 default in this stack) and a positive byte model
    assert!(text.contains("# TYPE m2_bytes_streamed_per_token gauge"));
    assert!(metric_value(
        &text,
        "m2_bytes_streamed_per_token{replica=\"0\",dtype=\"f32\"}")
        > 0.0);
    // and the cached second request decodes the same tokens
    let c1 = &j1.get("choices").and_then(Json::as_arr).unwrap()[0];
    let c2 = &j2.get("choices").and_then(Json::as_arr).unwrap()[0];
    assert_eq!(token_ids(c1), token_ids(c2),
               "prefix-cache hit changed the decode");
    h.drain().unwrap();
}

#[test]
fn echo_prepends_the_prompt_on_both_paths() {
    let s = fx();
    let (st, _, j) = post(&s.http,
        r#"{"model":"tiny","prompt":"echo this","max_tokens":3,"echo":true}"#);
    assert_eq!(st, 200, "{j}");
    let choice = &j.get("choices").and_then(Json::as_arr).unwrap()[0];
    let text = choice.get("text").and_then(Json::as_str).unwrap();
    assert!(text.starts_with("echo this"), "{text}");
    // usage counts generated tokens only; token_ids carries prompt +
    // completion when echoing
    let ids = token_ids(choice);
    let gen = j.at(&["usage", "completion_tokens"])
        .and_then(Json::as_u64).unwrap();
    assert_eq!(gen, 3);
    assert!(ids.len() > 3, "echo must prepend prompt ids: {ids:?}");
}
