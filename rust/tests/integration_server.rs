//! Integration: TCP line-JSON server end-to-end (bind :0, real sockets),
//! hermetically on the pure-Rust reference backend (no artifacts needed).
//! Covers protocol v1 byte-compatibility and the v2 surface: streaming
//! deltas + usage frames, multiplexed ids, cancellation (op and
//! disconnect) freeing slots mid-decode, stop tokens/strings, echo.

use std::io::{BufRead, BufReader, Write};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use mamba2_serve::coordinator::{Engine, EngineConfig, GenerateParams,
                                Router};
use mamba2_serve::eval::{corpus, Tokenizer};
use mamba2_serve::runtime::{Backend, ReferenceBackend};
use mamba2_serve::server::{Client, Frame, Server};
use mamba2_serve::util::json::Json;

fn spawn_server(batch_cap: usize) -> String {
    let session: Box<dyn Backend> =
        Box::new(ReferenceBackend::seeded("tiny", 0).unwrap());
    let eng = Arc::new(Engine::start(session, EngineConfig {
        batch_cap,
        ..Default::default()
    }).unwrap());
    let router = Arc::new(Router::new(vec![eng]));
    let tok = Arc::new(Tokenizer::train(corpus::BUNDLED, 64));
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let server = Server::new(router, tok);
        server.serve("127.0.0.1:0", 4, move |addr| {
            tx.send(addr.to_string()).unwrap();
        }).unwrap();
    });
    rx.recv_timeout(Duration::from_secs(30)).expect("server bound")
}

/// Shared default server (batch_cap 4) for tests that don't need slot
/// starvation; cancellation tests spawn their own cap-1 servers.
fn addr() -> String {
    static A: OnceLock<String> = OnceLock::new();
    A.get_or_init(|| spawn_server(4)).clone()
}

/// Poll the `metrics` op until `field` (on replica 0) reaches `want`.
fn wait_replica_metric(addr: &str, field: &str, want: f64) {
    let mut c = Client::connect(addr).unwrap();
    let t0 = Instant::now();
    loop {
        let m = c.call(&Json::obj(vec![("op", Json::str("metrics"))]))
            .unwrap();
        let v = m.get("replicas").and_then(Json::as_arr).unwrap()[0]
            .get(field).and_then(Json::as_f64).unwrap();
        if v >= want {
            return;
        }
        assert!(t0.elapsed() < Duration::from_secs(30),
                "timed out waiting for {field} >= {want} (at {v})");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn ping() {
    let mut c = Client::connect(&addr()).unwrap();
    assert!(c.ping().unwrap());
}

#[test]
fn generate_roundtrip() {
    let mut c = Client::connect(&addr()).unwrap();
    let r = c.generate("state space", 6).unwrap();
    assert!(r.get("error").is_none(), "{r}");
    assert_eq!(r.get("n").and_then(Json::as_u64), Some(6));
    assert_eq!(r.get("tokens").and_then(Json::as_arr).unwrap().len(), 6);
}

#[test]
fn v1_response_shape_is_byte_compatible() {
    // a v1 request (no v2 fields) must answer with exactly the v1 keys
    let stream = std::net::TcpStream::connect(addr()).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    writeln!(w, r#"{{"op":"generate","prompt":"state","max_new_tokens":4}}"#)
        .unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    let keys: Vec<&str> = j.as_obj().unwrap()
        .keys().map(|k| k.as_str()).collect();
    assert_eq!(keys, vec!["ms", "n", "text", "tokens"],
               "v1 response shape changed: {line}");
}

#[test]
fn concurrent_clients() {
    let addr = addr();
    let mut handles = Vec::new();
    for i in 0..4 {
        let a = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&a).unwrap();
            let r = c.generate(&format!("prompt {i}"), 4).unwrap();
            assert!(r.get("error").is_none(), "{r}");
            r.get("n").and_then(Json::as_u64).unwrap()
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), 4);
    }
}

#[test]
fn metrics_endpoint() {
    let mut c = Client::connect(&addr()).unwrap();
    // ensure at least one request happened
    let _ = c.generate("x", 2).unwrap();
    let m = c.call(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
    let reps = m.get("replicas").and_then(Json::as_arr).unwrap();
    assert_eq!(reps.len(), 1);
    assert!(reps[0].get("tokens").and_then(Json::as_f64).unwrap() >= 2.0);
    // v2 additions: queue_depth / in_flight / cancelled per replica,
    // conn_errors for the server itself
    assert!(reps[0].get("queue_depth").and_then(Json::as_f64).is_some());
    assert!(reps[0].get("in_flight").and_then(Json::as_f64).is_some());
    assert!(reps[0].get("cancelled").and_then(Json::as_f64).is_some());
    assert!(m.get("conn_errors").and_then(Json::as_f64).is_some());
}

#[test]
fn malformed_json_gets_error_not_disconnect() {
    let stream = std::net::TcpStream::connect(addr()).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    writeln!(w, "this is not json").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("error"));
    // connection still alive:
    writeln!(w, "{}", Json::obj(vec![("op", Json::str("ping"))])).unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("true"));
}

#[test]
fn protocol_error_mid_connection_keeps_streaming_usable() {
    // an erroring op mid-connection must not kill a later streaming
    // generate (raw malformed JSON is covered above)
    let mut c = Client::connect(&addr()).unwrap();
    c.call(&Json::parse("{\"op\":\"nonsense\"}").unwrap()).unwrap();
    let mut s = c.generate_stream("state space",
                                  &GenerateParams::new().max_new_tokens(3))
        .unwrap();
    let mut n = 0;
    for f in &mut s {
        match f.unwrap() {
            Frame::Delta { tokens, .. } => n += tokens.len(),
            Frame::Done { finish_reason, .. } => {
                assert_eq!(finish_reason, "length");
            }
            Frame::Error(e) => panic!("stream error: {e}"),
        }
    }
    assert_eq!(n, 3);
}

#[test]
fn unknown_op_is_error() {
    let mut c = Client::connect(&addr()).unwrap();
    let r = c.call(&Json::obj(vec![("op", Json::str("frobnicate"))])).unwrap();
    assert!(r.get("error").is_some());
}

// -------------------------------------------------------- streaming ---

#[test]
fn streaming_delta_per_step_with_final_usage_frame() {
    let mut c = Client::connect(&addr()).unwrap();
    // blocking reference for the same deterministic greedy request
    let want = c.generate("state space", 6).unwrap();
    let want_text = want.get("text").and_then(Json::as_str).unwrap()
        .to_string();

    let mut s = c.generate_stream("state space",
                                  &GenerateParams::new().max_new_tokens(6))
        .unwrap();
    let mut n_tokens = 0;
    let mut n_deltas = 0;
    let mut text = String::new();
    let mut done: Option<(String, Json)> = None;
    while let Some(f) = s.next_frame().unwrap() {
        match f {
            Frame::Delta { tokens, text: t } => {
                n_deltas += 1;
                n_tokens += tokens.len();
                text.push_str(&t);
            }
            Frame::Done { finish_reason, usage } => {
                done = Some((finish_reason, usage));
            }
            Frame::Error(e) => panic!("stream error: {e}"),
        }
    }
    // ≥ 1 delta frame per decode step: 6 tokens, one token per step
    assert_eq!(n_tokens, 6);
    assert!(n_deltas >= 6, "expected one delta per decode step, got \
                            {n_deltas}");
    assert_eq!(text, want_text,
               "streamed text must equal the blocking result");
    let (reason, usage) = done.expect("final usage frame");
    assert_eq!(reason, "length");
    assert!(usage.get("prompt_tokens").and_then(Json::as_u64).unwrap() >= 1);
    assert_eq!(usage.get("completion_tokens").and_then(Json::as_u64),
               Some(6));
    let ttft = usage.get("ttft_ms").and_then(Json::as_f64).unwrap();
    let e2e = usage.get("e2e_ms").and_then(Json::as_f64).unwrap();
    assert!(ttft > 0.0 && e2e >= ttft, "ttft {ttft} e2e {e2e}");
}

#[test]
fn two_streams_multiplex_one_connection() {
    // dedicated server so scheduling is not perturbed by other tests
    let addr = spawn_server(4);
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    // long stream first, short one right behind it on the same socket
    writeln!(w, r#"{{"op":"generate","prompt":"state space model","max_new_tokens":60,"stream":true,"id":1}}"#).unwrap();
    writeln!(w, r#"{{"op":"generate","prompt":"another prompt","max_new_tokens":5,"stream":true,"id":2}}"#).unwrap();
    let mut counts = [0usize; 3];
    let mut done_order = Vec::new();
    let mut line = String::new();
    while done_order.len() < 2 {
        line.clear();
        assert!(r.read_line(&mut line).unwrap() > 0, "server closed");
        let j = Json::parse(line.trim()).unwrap();
        let id = j.get("id").and_then(Json::as_u64).unwrap() as usize;
        assert!(id == 1 || id == 2, "unexpected id {id}");
        if let Some(d) = j.get("delta") {
            counts[id] += d.get("tokens").and_then(Json::as_arr)
                .unwrap().len();
        } else if j.get("done").and_then(Json::as_bool) == Some(true) {
            done_order.push(id);
        }
    }
    assert_eq!(counts[1], 60, "stream 1 token count");
    assert_eq!(counts[2], 5, "stream 2 token count");
    // frames interleave by id: the short stream finishes while the long
    // one is still decoding
    assert_eq!(done_order, vec![2, 1],
               "streams did not interleave: {done_order:?}");
}

// ----------------------------------------------------- cancellation ---

#[test]
fn cancel_op_frees_slot_mid_decode() {
    // cap-1 server: if the cancelled stream leaked its slot, the
    // follow-up generate could never be admitted
    let addr = spawn_server(1);
    let mut c = Client::connect(&addr).unwrap();
    let huge = 100_000;
    let mut s = c.generate_stream(
        "state space",
        &GenerateParams::new().max_new_tokens(huge)).unwrap();
    // let it decode a little, then cancel mid-stream
    let mut n_tokens = 0;
    let mut finish = String::new();
    let mut usage = Json::Null;
    while let Some(f) = s.next_frame().unwrap() {
        match f {
            Frame::Delta { tokens, .. } => {
                n_tokens += tokens.len();
                if n_tokens == 2 {
                    s.cancel().unwrap();
                }
            }
            Frame::Done { finish_reason, usage: u } => {
                finish = finish_reason;
                usage = u;
            }
            Frame::Error(e) => panic!("stream error: {e}"),
        }
    }
    assert_eq!(finish, "cancelled");
    assert!(n_tokens < huge,
            "cancel must land before max_new_tokens ({n_tokens})");
    assert!(usage.get("completion_tokens").and_then(Json::as_u64)
            .unwrap() < huge as u64);
    // slot reuse on the single slot — this would hang forever if the
    // cancel had not freed it
    let r = c.generate("state", 4).unwrap();
    assert_eq!(r.get("n").and_then(Json::as_u64), Some(4));
    wait_replica_metric(&addr, "cancelled", 1.0);
}

#[test]
fn cancel_unknown_id_returns_structured_error() {
    let mut c = Client::connect(&addr()).unwrap();
    let r = c.call(&Json::obj(vec![
        ("op", Json::str("cancel")),
        ("id", Json::num(987654.0)),
    ])).unwrap();
    assert_eq!(r.get("id").and_then(Json::as_u64), Some(987654));
    assert!(r.get("error").and_then(Json::as_str).unwrap()
            .contains("unknown"));
    // connection still usable afterwards
    assert!(c.ping().unwrap());
}

#[test]
fn client_disconnect_cancels_inflight_and_frees_slot() {
    let addr = spawn_server(1);
    {
        let stream = std::net::TcpStream::connect(&addr).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = stream;
        writeln!(w, r#"{{"op":"generate","prompt":"state","max_new_tokens":100000,"stream":true,"id":9}}"#).unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap(); // first delta: it is decoding
        assert!(line.contains("delta"), "{line}");
        // drop both halves: client walks away mid-stream
    }
    wait_replica_metric(&addr, "cancelled", 1.0);
    // the slot must be free for a fresh connection
    let mut c = Client::connect(&addr).unwrap();
    let r = c.generate("state", 3).unwrap();
    assert_eq!(r.get("n").and_then(Json::as_u64), Some(3));
}

// ------------------------------------------- stop tokens and strings ---

#[test]
fn stop_token_via_wire_protocol() {
    let mut c = Client::connect(&addr()).unwrap();
    let base = c.generate("state space", 8).unwrap();
    let toks = base.get("tokens").and_then(Json::as_arr).unwrap();
    assert_eq!(toks.len(), 8);
    let stop = toks[2].as_i64().unwrap() as i32;
    let r = c.generate_with("state space",
                            &GenerateParams::new().max_new_tokens(8)
                                .stop_token(stop)).unwrap();
    assert_eq!(r.get("n").and_then(Json::as_u64), Some(3),
               "stop token must end generation early: {r}");
    assert_eq!(r.get("finish_reason").and_then(Json::as_str),
               Some("stop_token"));
    let got = r.get("tokens").and_then(Json::as_arr).unwrap();
    assert_eq!(got.last().unwrap().as_i64().unwrap() as i32, stop);
}

#[test]
fn stop_string_truncates_even_across_token_boundary() {
    let mut c = Client::connect(&addr()).unwrap();
    let base = c.generate("state space", 16).unwrap();
    let text = base.get("text").and_then(Json::as_str).unwrap().to_string();
    let toks: Vec<i32> = base.get("tokens").and_then(Json::as_arr).unwrap()
        .iter().map(|t| t.as_i64().unwrap() as i32).collect();
    // reconstruct the server's tokenizer (training is deterministic) to
    // find a stop string that SPANS a token boundary: last char of one
    // token's text + first char of the next token's text
    let tok = Tokenizer::train(corpus::BUNDLED, 64);
    let pieces: Vec<String> = toks.iter().map(|&t| tok.decode(&[t]))
        .collect();
    assert_eq!(pieces.concat(), text, "incremental decode must concat");
    let mut stop: Option<String> = None;
    for w in pieces.windows(2) {
        if let (Some(a), Some(b)) = (w[0].chars().last(), w[1].chars().next())
        {
            stop = Some(format!("{a}{b}"));
            break;
        }
    }
    // fall back to any interior 2-char window (still exercises the wire
    // path) if the model only produced out-of-vocab/empty pieces
    let stop = stop.or_else(|| {
        let cs: Vec<char> = text.chars().collect();
        (cs.len() >= 2).then(|| cs[..2].iter().collect())
    });
    let Some(stop) = stop else {
        eprintln!("skipping: generated text too short for a stop string");
        return;
    };
    let cut = text.find(&stop).expect("stop string comes from the text");
    let want = &text[..cut];

    let r = c.generate_with("state space",
                            &GenerateParams::new().max_new_tokens(16)
                                .stop_string(stop.clone())).unwrap();
    assert_eq!(r.get("finish_reason").and_then(Json::as_str),
               Some("stop_string"), "{r}");
    assert_eq!(r.get("text").and_then(Json::as_str), Some(want),
               "text must truncate exactly at the first {stop:?} match");
    // and the token list never leaks past the match
    let got_n = r.get("n").and_then(Json::as_u64).unwrap();
    assert!(got_n <= 16);
    // streamed variant agrees with the blocking one
    let mut s = c.generate_stream(
        "state space",
        &GenerateParams::new().max_new_tokens(16)
            .stop_string(stop.clone())).unwrap();
    let mut streamed = String::new();
    let mut finish = String::new();
    while let Some(f) = s.next_frame().unwrap() {
        match f {
            Frame::Delta { text: t, .. } => streamed.push_str(&t),
            Frame::Done { finish_reason, .. } => finish = finish_reason,
            Frame::Error(e) => panic!("stream error: {e}"),
        }
    }
    assert_eq!(finish, "stop_string");
    assert_eq!(streamed, want, "streamed deltas must truncate identically");
}

// -------------------------------------------- session save / resume ---

#[test]
fn session_save_resume_over_the_wire_matches_generate() {
    let mut c = Client::connect(&addr()).unwrap();
    let want = c.generate("state space", 6).unwrap();
    let want_toks = want.get("tokens").and_then(Json::as_arr).unwrap();

    let s = c.session_save("state space").unwrap();
    assert!(s.get("error").is_none(), "{s}");
    assert_eq!(s.get("config").and_then(Json::as_str), Some("tiny"));
    let pos = s.get("position").and_then(Json::as_u64).unwrap();
    assert!(pos >= 1, "position counts the prompt tokens");
    let n_bytes = s.get("n_bytes").and_then(Json::as_u64).unwrap();
    let hex = s.get("session").and_then(Json::as_str).unwrap().to_string();
    assert_eq!(hex.len() as u64, 2 * n_bytes);

    // resume with no continuation: the first token comes from the saved
    // logits row, so the whole greedy stream must equal plain generate
    let r = c.session_resume(&hex, "",
                             &GenerateParams::new().max_new_tokens(6))
        .unwrap();
    assert!(r.get("error").is_none(), "{r}");
    let got = r.get("tokens").and_then(Json::as_arr).unwrap();
    assert_eq!(got, want_toks,
               "resumed generation diverged from uninterrupted one");
}

#[test]
fn malformed_session_resume_gets_error_not_disconnect() {
    // a valid blob to corrupt, fetched on its own connection
    let hex = {
        let mut c = Client::connect(&addr()).unwrap();
        let s = c.session_save("state space").unwrap();
        s.get("session").and_then(Json::as_str).unwrap().to_string()
    };
    let mut corrupt = hex.clone();
    let mid = corrupt.len() / 2;
    let flip = if corrupt.as_bytes()[mid] == b'0' { "1" } else { "0" };
    corrupt.replace_range(mid..mid + 1, flip);

    let stream = std::net::TcpStream::connect(addr()).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    let cases: Vec<String> = vec![
        // no blob at all
        r#"{"op":"session_resume","max_new_tokens":4}"#.into(),
        // not hex
        r#"{"op":"session_resume","session":"zz","max_new_tokens":4}"#
            .into(),
        // odd-length hex
        r#"{"op":"session_resume","session":"4d2","max_new_tokens":4}"#
            .into(),
        // valid hex, truncated blob
        r#"{"op":"session_resume","session":"4d02","max_new_tokens":4}"#
            .into(),
        // full-length blob with one flipped nibble (checksum catches it)
        format!("{{\"op\":\"session_resume\",\"session\":\"{corrupt}\",\
                 \"max_new_tokens\":4}}"),
    ];
    let mut line = String::new();
    for case in &cases {
        writeln!(w, "{case}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert!(j.get("error").and_then(Json::as_str).is_some(),
                "case {case} must answer a structured error: {line}");
    }
    // the connection survived all of it — and still generates
    writeln!(w, "{}", Json::obj(vec![("op", Json::str("ping"))])).unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("true"), "{line}");
    // and the GOOD blob still resumes on a fresh client
    let mut c = Client::connect(&addr()).unwrap();
    let r = c.session_resume(&hex, "",
                             &GenerateParams::new().max_new_tokens(3))
        .unwrap();
    assert!(r.get("error").is_none(), "{r}");
}

#[test]
fn metrics_exposes_prefix_cache_block() {
    let mut c = Client::connect(&addr()).unwrap();
    let _ = c.generate("state space model", 2).unwrap();
    let m = c.call(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
    let pc = m.get("replicas").and_then(Json::as_arr).unwrap()[0]
        .get("prefix_cache").expect("prefix_cache block");
    for field in ["hits", "misses", "evictions", "insertions", "bytes",
                  "entries"] {
        assert!(pc.get(field).and_then(Json::as_f64).is_some(),
                "prefix_cache.{field} missing: {pc}");
    }
}

// ------------------------------------------------------------- echo ---

#[test]
fn echo_prepends_prompt() {
    let mut c = Client::connect(&addr()).unwrap();
    let plain = c.generate("state space", 4).unwrap();
    let plain_text = plain.get("text").and_then(Json::as_str).unwrap()
        .to_string();
    let r = c.generate_with("state space",
                            &GenerateParams::new().max_new_tokens(4)
                                .echo(true)).unwrap();
    let text = r.get("text").and_then(Json::as_str).unwrap();
    assert_eq!(text, format!("state space{plain_text}"));
    // n stays the completion count; tokens include the prompt
    assert_eq!(r.get("n").and_then(Json::as_u64), Some(4));
    let usage = r.get("usage").unwrap();
    let p = usage.get("prompt_tokens").and_then(Json::as_u64).unwrap();
    assert_eq!(r.get("tokens").and_then(Json::as_arr).unwrap().len() as u64,
               p + 4);
}
