//! Integration: TCP line-JSON server end-to-end (bind :0, real sockets),
//! hermetically on the pure-Rust reference backend (no artifacts needed).

use std::sync::{Arc, OnceLock};
use std::time::Duration;

use mamba2_serve::coordinator::{Engine, EngineConfig, Router};
use mamba2_serve::eval::{corpus, Tokenizer};
use mamba2_serve::runtime::{Backend, ReferenceBackend};
use mamba2_serve::server::{Client, Server};
use mamba2_serve::util::json::Json;

fn start_server() -> String {
    let session: Box<dyn Backend> =
        Box::new(ReferenceBackend::seeded("tiny", 0).unwrap());
    let eng = Arc::new(Engine::start(session, EngineConfig::default())
                       .unwrap());
    let router = Arc::new(Router::new(vec![eng]));
    let tok = Arc::new(Tokenizer::train(corpus::BUNDLED, 64));
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let server = Server::new(router, tok);
        server.serve("127.0.0.1:0", 4, move |addr| {
            tx.send(addr.to_string()).unwrap();
        }).unwrap();
    });
    rx.recv_timeout(Duration::from_secs(30)).expect("server bound")
}

fn addr() -> String {
    static A: OnceLock<String> = OnceLock::new();
    A.get_or_init(start_server).clone()
}

#[test]
fn ping() {
    let mut c = Client::connect(&addr()).unwrap();
    assert!(c.ping().unwrap());
}

#[test]
fn generate_roundtrip() {
    let mut c = Client::connect(&addr()).unwrap();
    let r = c.generate("state space", 6).unwrap();
    assert!(r.get("error").is_none(), "{r}");
    assert_eq!(r.get("n").and_then(Json::as_u64), Some(6));
    assert_eq!(r.get("tokens").and_then(Json::as_arr).unwrap().len(), 6);
}

#[test]
fn concurrent_clients() {
    let addr = addr();
    let mut handles = Vec::new();
    for i in 0..4 {
        let a = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&a).unwrap();
            let r = c.generate(&format!("prompt {i}"), 4).unwrap();
            assert!(r.get("error").is_none(), "{r}");
            r.get("n").and_then(Json::as_u64).unwrap()
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), 4);
    }
}

#[test]
fn metrics_endpoint() {
    let mut c = Client::connect(&addr()).unwrap();
    // ensure at least one request happened
    let _ = c.generate("x", 2).unwrap();
    let m = c.call(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
    let reps = m.get("replicas").and_then(Json::as_arr).unwrap();
    assert_eq!(reps.len(), 1);
    assert!(reps[0].get("tokens").and_then(Json::as_f64).unwrap() >= 2.0);
}

#[test]
fn malformed_json_gets_error_not_disconnect() {
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(addr()).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    writeln!(w, "this is not json").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("error"));
    // connection still alive:
    writeln!(w, "{}", Json::obj(vec![("op", Json::str("ping"))])).unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("true"));
}

#[test]
fn unknown_op_is_error() {
    let mut c = Client::connect(&addr()).unwrap();
    let r = c.call(&Json::obj(vec![("op", Json::str("frobnicate"))])).unwrap();
    assert!(r.get("error").is_some());
}
