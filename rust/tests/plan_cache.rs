//! Plan-cache behaviour end-to-end (ISSUE 4): shape buckets reuse one
//! plan, distinct buckets never collide, residency is bounded, and the
//! engine's start-up warm-up pre-populates every registered bucket so
//! no first request pays planning latency.

use std::sync::{Arc, Mutex};

use mamba2_serve::coordinator::{Engine, EngineConfig, GenerateParams};
use mamba2_serve::runtime::plan::MAX_PLANS;
use mamba2_serve::runtime::{Backend, CacheState, ConfigInfo, PlanStats,
                            PrefillOut, ReferenceBackend, StepOut};
use mamba2_serve::tensor::Tensor;
use mamba2_serve::util::error::Result;

fn backend() -> ReferenceBackend {
    ReferenceBackend::seeded("tiny", 0).unwrap().with_threads(2)
}

#[test]
fn same_bucket_reuses_one_plan() {
    let b = backend();
    let toks: Vec<i32> = (0..64).collect();
    for _ in 0..5 {
        b.prefill(&toks, 1).unwrap();
    }
    let s = b.plan_stats().unwrap();
    assert_eq!(s.built, 1, "one shape bucket, one plan");
    assert_eq!(s.hits, 4);
    assert_eq!(s.cached, 1);
}

#[test]
fn distinct_buckets_do_not_collide() {
    let b = backend();
    // three prefill shapes + two decode widths = five distinct keys
    b.prefill(&(0..16).collect::<Vec<i32>>(), 1).unwrap();
    b.prefill(&(0..32).collect::<Vec<i32>>(), 1).unwrap();
    b.prefill(&(0..32).collect::<Vec<i32>>(), 2).unwrap();
    let pre = b.prefill(&(0..16).collect::<Vec<i32>>(), 1).unwrap();
    for w in [1usize, 3] {
        let mut cache = CacheState::zeros(b.cfg(), w);
        for s in 0..w {
            cache.copy_slot_from(s, &pre.cache, 0);
        }
        let toks: Vec<i32> = (0..w as i32).collect();
        b.decode_step(&cache, &toks).unwrap();
    }
    let s = b.plan_stats().unwrap();
    assert_eq!(s.built, 5, "five shape keys, five plans");
    // dumps confirm the keys really differ
    let d16 = b.plan_dump("prefill", 16, 1).unwrap();
    let d32 = b.plan_dump("prefill", 32, 1).unwrap();
    assert_ne!(d16, d32);
    assert!(d16.contains("t=16") && d32.contains("t=32"));
}

#[test]
fn cache_residency_is_bounded() {
    let b = backend();
    let pre = b.prefill(&(0..16).collect::<Vec<i32>>(), 1).unwrap();
    // drive more decode widths than the cache may hold resident
    for w in 1..=MAX_PLANS + 4 {
        let mut cache = CacheState::zeros(b.cfg(), w);
        for s in 0..w {
            cache.copy_slot_from(s, &pre.cache, 0);
        }
        let toks: Vec<i32> = vec![1; w];
        b.decode_step(&cache, &toks).unwrap();
    }
    let s = b.plan_stats().unwrap();
    assert!(s.built as usize >= MAX_PLANS + 4);
    assert!(s.cached <= MAX_PLANS, "cache must stay bounded, \
             got {} resident", s.cached);
}

// ---------------------------------------------------- engine warm-up ----

/// Records `warm_up` calls, then delegates everything to the reference
/// backend — proves the engine performs plan warm-up at shape-bucket
/// registration with the width it will actually pack.
struct WarmupProbe {
    inner: ReferenceBackend,
    calls: Arc<Mutex<Vec<usize>>>,
}

impl Backend for WarmupProbe {
    fn name(&self) -> &'static str {
        "warmup-probe"
    }
    fn platform(&self) -> String {
        self.inner.platform()
    }
    fn cfg(&self) -> &ConfigInfo {
        self.inner.cfg()
    }
    fn batch_cap(&self) -> usize {
        self.inner.batch_cap()
    }
    fn prefill_buckets(&self) -> Vec<usize> {
        self.inner.prefill_buckets()
    }
    fn decode_loop_buckets(&self) -> Vec<usize> {
        self.inner.decode_loop_buckets()
    }
    fn forward_buckets(&self) -> Vec<usize> {
        self.inner.forward_buckets()
    }
    fn load_weights(&mut self, tensors: Vec<Tensor>) -> Result<()> {
        self.inner.load_weights(tensors)
    }
    fn prefill(&self, tokens: &[i32], batch: usize)
        -> Result<PrefillOut> {
        self.inner.prefill(tokens, batch)
    }
    fn prefill_continue(&self, cache: &CacheState, tokens: &[i32],
                        batch: usize) -> Result<PrefillOut> {
        self.inner.prefill_continue(cache, tokens, batch)
    }
    fn decode_step(&self, cache: &CacheState, tokens: &[i32])
        -> Result<StepOut> {
        self.inner.decode_step(cache, tokens)
    }
    fn decode_width(&self, active: usize) -> usize {
        self.inner.decode_width(active)
    }
    fn decode_loop(&self, cache: &CacheState, token: i32, bucket: usize)
        -> Result<(Vec<i32>, CacheState)> {
        self.inner.decode_loop(cache, token, bucket)
    }
    fn forward_full(&self, tokens: &[i32]) -> Result<Tensor> {
        self.inner.forward_full(tokens)
    }
    fn warm_up(&self, max_decode_width: usize) {
        self.calls.lock().unwrap().push(max_decode_width);
        self.inner.warm_up(max_decode_width);
    }
    fn plan_stats(&self) -> Option<PlanStats> {
        self.inner.plan_stats()
    }
}

#[test]
fn engine_start_warms_every_registered_bucket() {
    let calls = Arc::new(Mutex::new(Vec::new()));
    let probe = WarmupProbe { inner: backend(),
                              calls: Arc::clone(&calls) };
    let stats_probe = WarmupProbe { inner: probe.inner.clone(),
                                    calls: Arc::clone(&calls) };
    let eng = Engine::start(
        Box::new(probe),
        EngineConfig { batch_cap: 3, ..Default::default() }).unwrap();
    // warm-up ran synchronously during start, with the slot count the
    // engine will pack decode widths up to
    assert_eq!(calls.lock().unwrap().clone(), vec![3usize]);
    // a reference backend warmed the same way holds a plan for every
    // prefill bucket and every decode width 1..=3
    stats_probe.warm_up(3);
    let s = stats_probe.plan_stats().unwrap();
    let want = stats_probe.prefill_buckets().len() as u64 + 3;
    assert_eq!(s.built, want);
    assert_eq!(s.cached as u64, want);
    // and the engine still serves correctly after warm-up
    let stream = eng.generate((1..20).collect(),
                              GenerateParams::new().max_new_tokens(4));
    let toks = stream.collect().unwrap();
    assert_eq!(toks.len(), 4);
    eng.shutdown();
}

#[test]
fn warmed_buckets_never_replan_under_load() {
    // the serving-path property the warm-up exists for: after warm_up,
    // bucket-chained prefills and packed decodes are all cache hits
    let b = backend();
    b.warm_up(4);
    let built = b.plan_stats().unwrap().built;
    // 300 tokens chain buckets 256+16+16 with a 12-step width-1 tail
    // decode; all four shapes were warmed
    let prompt = vec![7i32; 300];
    let (cache, _) = b.prefill_any(&prompt).unwrap();
    let mut batched = CacheState::zeros(b.cfg(), 4);
    for s in 0..4 {
        batched.copy_slot_from(s, &cache, 0);
    }
    b.decode_step(&batched, &[1, 2, 3, 4]).unwrap();
    let s = b.plan_stats().unwrap();
    assert_eq!(s.built, built, "serving warmed buckets must not plan");
    assert!(s.hits > 0);
}
