//! Parity suite for the batch-fused decode and threadpool-parallel
//! prefill paths (DESIGN.md §2.2).
//!
//! The load-bearing claims:
//!
//!   * a batched decode step over any ragged set of packed slots (holes
//!     from mid-decode cancels, PR 2) is bit-identical to per-slot
//!     single-sequence decodes — slots never mix, batching only fuses the
//!     contractions,
//!   * the threadpool-parallel prefill matches the serial chunk scan
//!     exactly for any worker count — parallelism changes the schedule,
//!     never a bit of the result,
//!   * `prefill_any`'s greedy bucket chain (prefill + prefill_continue +
//!     tail decode) is bitwise equal to one joint chunked forward over
//!     the same prefix, and the engine's packed continuous batching
//!     preserves greedy outputs across admissions and cancels.
//!
//! The ISSUE acceptance bound is 1e-6; the reference backend achieves
//! bitwise equality, which the assertions pin directly.

use mamba2_serve::coordinator::{Engine, EngineConfig, GenerateParams,
                                SingleStream};
use mamba2_serve::runtime::{argmax_last, Backend, CacheState,
                            ReferenceBackend};

fn backend() -> ReferenceBackend {
    ReferenceBackend::seeded("tiny", 0).unwrap()
}

fn prompt(len: usize, salt: usize) -> Vec<i32> {
    (0..len).map(|i| ((i * 37 + 11 * salt + 5) % 512) as i32).collect()
}

/// Distinct prefilled single-sequence caches to populate batch slots.
fn seed_caches(b: &ReferenceBackend, n: usize) -> Vec<CacheState> {
    (0..n)
        .map(|i| b.prefill_any(&prompt(16 + 16 * (i % 2), i + 1))
            .unwrap().0)
        .collect()
}

#[test]
fn batched_decode_is_bitwise_per_slot_decode() {
    let b = backend();
    let v = b.cfg().vocab_size;
    for bsz in [1usize, 3, 4, 16] {
        let seeds = seed_caches(&b, bsz);
        let mut cache = CacheState::zeros(b.cfg(), bsz);
        for (s, seed) in seeds.iter().enumerate() {
            cache.copy_slot_from(s, seed, 0);
        }
        let tokens: Vec<i32> =
            (0..bsz).map(|i| ((i * 31 + 7) % 512) as i32).collect();
        let batched = b.decode_step(&cache, &tokens).unwrap();
        let bl = batched.logits.as_f32();
        for (s, seed) in seeds.iter().enumerate() {
            let single = b.decode_step(seed, &tokens[s..=s]).unwrap();
            assert_eq!(&bl[s * v..(s + 1) * v],
                       &single.logits.as_f32()[..],
                       "B={bsz} slot {s}: batched logits != per-slot");
            let mut got = CacheState::zeros(b.cfg(), 1);
            got.copy_slot_from(0, &batched.cache, s);
            assert_eq!(got.ssm.as_f32(), single.cache.ssm.as_f32(),
                       "B={bsz} slot {s}: ssm state diverged");
            assert_eq!(got.conv.as_f32(), single.cache.conv.as_f32(),
                       "B={bsz} slot {s}: conv state diverged");
        }
    }
}

#[test]
fn ragged_packed_decode_matches_full_width() {
    // the engine's packing step for a slot set with holes: gathering
    // {0, 2, 5} of an 8-wide cache and decoding B=3 must equal the same
    // slots of a full-width B=8 decode (dummy tokens elsewhere)
    let b = backend();
    let v = b.cfg().vocab_size;
    let seeds = seed_caches(&b, 8);
    let mut full = CacheState::zeros(b.cfg(), 8);
    for (s, seed) in seeds.iter().enumerate() {
        full.copy_slot_from(s, seed, 0);
    }
    let live = [0usize, 2, 5];
    let mut full_tokens = vec![0i32; 8];
    let mut packed_tokens = Vec::new();
    for &s in &live {
        let tok = ((s * 13 + 1) % 512) as i32;
        full_tokens[s] = tok;
        packed_tokens.push(tok);
    }
    let wide = b.decode_step(&full, &full_tokens).unwrap();
    let packed_cache = full.gather_slots(&live);
    let packed = b.decode_step(&packed_cache, &packed_tokens).unwrap();
    let wl = wide.logits.as_f32();
    let pl = packed.logits.as_f32();
    for (j, &s) in live.iter().enumerate() {
        assert_eq!(&pl[j * v..(j + 1) * v], &wl[s * v..(s + 1) * v],
                   "packed row {j} != full-width slot {s}");
    }
    // scattering the packed result back reproduces the wide cache at the
    // live slots
    let mut scattered = full.clone();
    scattered.scatter_slots(&live, &packed.cache);
    let ws = wide.cache.ssm.as_f32();
    let ss = scattered.ssm.as_f32();
    let per: usize =
        full.ssm.dims[2..].iter().product::<i64>() as usize;
    for layer in 0..b.cfg().n_layer {
        for &s in &live {
            let base = (layer * 8 + s) * per;
            assert_eq!(&ss[base..base + per], &ws[base..base + per],
                       "scattered ssm slot {s} layer {layer}");
        }
    }
}

#[test]
fn parallel_prefill_matches_serial_scan_exactly() {
    // same weights, same inputs, 1 worker vs many: every logit and every
    // cache byte must match bitwise, for single and multi-sequence
    // batches and for chained (continued) segments
    let serial = backend().with_threads(1);
    let parallel = backend().with_threads(8);
    for (batch, t) in [(1usize, 64usize), (2, 64), (4, 32)] {
        let toks: Vec<i32> = (0..batch * t)
            .map(|i| ((i * 17 + 3) % 512) as i32).collect();
        let a = serial.prefill(&toks, batch).unwrap();
        let b = parallel.prefill(&toks, batch).unwrap();
        assert_eq!(a.logits.as_f32(), b.logits.as_f32(),
                   "prefill logits B={batch} T={t}");
        assert_eq!(a.cache.ssm.as_f32(), b.cache.ssm.as_f32());
        assert_eq!(a.cache.conv.as_f32(), b.cache.conv.as_f32());
        let cont: Vec<i32> = (0..batch * 16)
            .map(|i| ((i * 29 + 1) % 512) as i32).collect();
        let ca = serial.prefill_continue(&a.cache, &cont, batch).unwrap();
        let cb = parallel.prefill_continue(&b.cache, &cont, batch)
            .unwrap();
        assert_eq!(ca.logits.as_f32(), cb.logits.as_f32(),
                   "continued prefill B={batch}");
        assert_eq!(ca.cache.ssm.as_f32(), cb.cache.ssm.as_f32());
    }
}

#[test]
fn bucket_chain_prefill_any_is_bitwise_joint_forward() {
    // len 100 chains buckets 64+16+16 and tail-decodes 4; the chained
    // prefix must equal one joint chunked forward over 96 tokens bitwise
    // (same chunk grid, carry transported through the O(1) cache), and
    // the remaining policy must equal a manual replay
    let b = backend();
    let toks = prompt(100, 3);
    let (cache, last) = b.prefill_any(&toks).unwrap();
    let joint = b.prefill(&toks[..96], 1).unwrap();
    let mut c2 = joint.cache;
    let mut l2 = None;
    for pos in 96..100 {
        let s = b.decode_step(&c2, &toks[pos..=pos]).unwrap();
        c2 = s.cache;
        l2 = Some(s.logits);
    }
    assert_eq!(last.as_f32(), l2.unwrap().as_f32(),
               "bucket-chained prefill_any != joint forward + steps");
    assert_eq!(cache.ssm.as_f32(), c2.ssm.as_f32());
    assert_eq!(cache.conv.as_f32(), c2.conv.as_f32());
}

#[test]
fn bucket_chain_preserves_greedy_outputs() {
    // decode strategies must agree on prompts whose length exercises the
    // chain (>= one bucket + remainder >= another bucket)
    let b = backend();
    let ss = SingleStream::new(&b);
    for len in [20usize, 100, 150] {
        let p = prompt(len, 1);
        let host = ss.generate_host(&p, 8).unwrap();
        let scan = ss.generate_scan(&p, 8).unwrap();
        assert_eq!(host, scan, "len {len}");
    }
}

#[test]
fn engine_packed_batching_with_cancels_preserves_outputs() {
    // engine-level ragged sets: run 4 concurrent greedy requests, cancel
    // one mid-decode (leaving a hole the packed decode must skip), and
    // check the survivors' outputs equal their solo runs
    let solo = backend();
    let ss = SingleStream::new(&solo);
    let prompts: Vec<Vec<i32>> =
        (0..4).map(|i| prompt(12 + i, i + 1)).collect();
    let want: Vec<Vec<i32>> = prompts.iter()
        .map(|p| ss.generate_host(p, 12).unwrap()).collect();

    let eng = Engine::start(Box::new(backend()), EngineConfig {
        batch_cap: 4,
        ..Default::default()
    }).unwrap();
    let streams: Vec<_> = prompts.iter()
        .map(|p| eng.generate(p.clone(),
                              GenerateParams::new().max_new_tokens(12)))
        .collect();
    let mut streams: Vec<Option<_>> =
        streams.into_iter().map(Some).collect();
    // give request 2 a head start, then cancel it mid-decode
    std::thread::sleep(std::time::Duration::from_millis(20));
    drop(streams[2].take());
    for (i, s) in streams.into_iter().enumerate() {
        let Some(s) = s else { continue };
        let got = s.collect().unwrap();
        assert_eq!(got, want[i],
                   "request {i} diverged under packed batching + cancel");
    }
}

#[test]
fn first_token_consistency_across_batch_widths() {
    // the argmax the engine samples from a packed row must match the
    // single-sequence path for every slot of a wide batch
    let b = backend();
    let seeds = seed_caches(&b, 6);
    let mut cache = CacheState::zeros(b.cfg(), 6);
    for (s, seed) in seeds.iter().enumerate() {
        cache.copy_slot_from(s, seed, 0);
    }
    let tokens: Vec<i32> = (0..6).map(|i| (i * 11 + 2) as i32).collect();
    let out = b.decode_step(&cache, &tokens).unwrap();
    let rows = argmax_last(&out.logits);
    for (s, seed) in seeds.iter().enumerate() {
        let single = b.decode_step(seed, &tokens[s..=s]).unwrap();
        assert_eq!(rows[s], argmax_last(&single.logits)[0], "slot {s}");
    }
}
