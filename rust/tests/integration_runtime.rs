//! Integration: rust XLA runtime vs python goldens over the real
//! artifacts.
//!
//! Tokens must match bitwise; logits/hidden state to the paper's Table 6
//! tolerances (1e-4 / 2e-4). Requires `make artifacts` and
//! `--features xla`; the whole file compiles away on the hermetic
//! default build (the backend-agnostic equivalents live in
//! integration_reference.rs).
#![cfg(feature = "xla")]

use std::path::Path;
use std::sync::{Arc, OnceLock};

use mamba2_serve::coordinator::SingleStream;
use mamba2_serve::runtime::{Backend, CacheState, ModelSession, Runtime};
use mamba2_serve::tensor::{find, load_mbt};

fn rt() -> Arc<Runtime> {
    static RT: OnceLock<Arc<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        Runtime::new(&mamba2_serve::artifacts_dir()).expect("artifacts")
    })
    .clone()
}

fn goldens() -> Vec<mamba2_serve::tensor::Tensor> {
    load_mbt(Path::new(&mamba2_serve::artifacts_dir())
             .join("goldens/tiny.mbt").as_path())
        .expect("goldens built by aot.py")
}

#[test]
fn manifest_validates() {
    let rt = rt();
    rt.manifest.validate().unwrap();
    assert!(rt.manifest.configs.contains_key("tiny"));
    assert!(rt.manifest.executables.len() >= 100);
}

#[test]
fn prefill_matches_python_logits() {
    let rt = rt();
    let session = ModelSession::new(rt, "tiny").unwrap();
    let g = goldens();
    let tokens = find(&g, "tokens").unwrap().as_i32();
    let want = find(&g, "prefill_logits").unwrap();
    // bucket policy: prefill(16) + 16 decode steps covers the 32-token
    // golden prompt exactly
    let (cache, last_logits) = session.prefill_any(&tokens).unwrap();
    // last-position logits vs golden row 31
    let v = *want.dims.last().unwrap() as usize;
    let wall = want.as_f32();
    let wrow = &wall[wall.len() - v..];
    let grow = last_logits.as_f32();
    let diff = wrow.iter().zip(&grow)
        .map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    // paper Table 6: logits agree to 2e-4 absolute
    assert!(diff < 2e-4, "logit diff {diff}");
    // cache states to float32 rounding
    let dssm = cache.ssm.max_abs_diff(find(&g, "cache_ssm").unwrap());
    assert!(dssm < 1e-4, "ssm diff {dssm}");
    let dconv = cache.conv.max_abs_diff(find(&g, "cache_conv").unwrap());
    assert!(dconv < 1e-5, "conv diff {dconv}");
}

#[test]
fn decode_loop_matches_python_tokens_bitwise() {
    let rt = rt();
    let session = ModelSession::new(rt, "tiny").unwrap();
    let g = goldens();
    let tokens = find(&g, "tokens").unwrap().as_i32();
    let want = find(&g, "gen_tokens").unwrap().as_i32();
    let (cache, last_logits) = session.prefill_any(&tokens).unwrap();
    let first = ModelSession::argmax_last(&last_logits)[0];
    let (gen, _) = session.decode_loop(&cache, first, 16).unwrap();
    assert_eq!(gen, want, "compiled-loop tokens must match python bitwise");
}

#[test]
fn host_loop_matches_scan_loop() {
    // paper §3.3: host-driven and compiled loops produce identical tokens
    let rt = rt();
    let session = ModelSession::new(rt, "tiny").unwrap();
    let g = goldens();
    let tokens = find(&g, "tokens").unwrap().as_i32();
    let ss = SingleStream::new(&session);
    let scan = ss.generate_scan(&tokens, 16).unwrap();
    let host = ss.generate_host(&tokens, 16).unwrap();
    assert_eq!(scan, host);
}

#[test]
fn forward_full_matches_prefill() {
    let rt = rt();
    let session = ModelSession::new(rt, "tiny").unwrap();
    let g = goldens();
    let tokens = find(&g, "tokens").unwrap().as_i32();
    let want = find(&g, "forward_full_logits").unwrap();
    let logits = session.forward_full(&tokens).unwrap();
    assert!(logits.max_abs_diff(want) < 2e-4);
}

#[test]
fn pallas_variant_agrees_with_jnp_path() {
    // L1 kernel parity at the executable level: the pallas-lowered prefill
    // must produce the same logits as the jnp-path artifact.
    let rt = rt();
    let session = ModelSession::new(Arc::clone(&rt), "tiny").unwrap();
    rt.load("ablation.pallas.prefill.t32").unwrap();
    let g = goldens();
    let tokens = find(&g, "tokens").unwrap();
    let outs = session
        .call_named("ablation.pallas.prefill.t32", vec![tokens.clone()])
        .unwrap();
    let want = find(&g, "prefill_logits").unwrap();
    assert!(outs[0].max_abs_diff(want) < 2e-4);
}

#[test]
fn decode_step_chain_matches_forward_full() {
    // the O(1) cache is exact: prefill(16) + 16 steps == forward_full(32)
    let rt = rt();
    let session = ModelSession::new(rt, "tiny").unwrap();
    let g = goldens();
    let tokens = find(&g, "tokens").unwrap().as_i32();
    let full = session.forward_full(&tokens).unwrap();
    let v = *full.dims.last().unwrap() as usize;
    let fv = full.as_f32();

    let pre = session.prefill(&tokens[..16], 1).unwrap();
    let mut cache = pre.cache;
    for (i, &tok) in tokens.iter().enumerate().skip(16) {
        let step = session.decode_step(&cache, &[tok]).unwrap();
        cache = step.cache;
        if i + 1 < tokens.len() {
            // logits at position i must match full forward row i... the
            // step consumed token i, so its logits predict position i+1
            let row_full = &fv[i * v..(i + 1) * v];
            let row_step = step.logits.as_f32();
            let d = row_full.iter().zip(&row_step)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(d < 2e-4, "pos {i} diff {d}");
        }
    }
}

#[test]
fn cache_is_constant_size() {
    let rt = rt();
    let cfg = rt.manifest.config("tiny").unwrap();
    let c1 = CacheState::zeros(cfg, 1);
    // paper Fig. 3: cache bytes do not depend on sequence length
    assert_eq!(c1.nbytes() as u64, cfg.cache_bytes_per_seq());
}

#[test]
fn literal_path_and_buffer_path_agree() {
    let rt = rt();
    let mut session = ModelSession::new(rt, "tiny").unwrap();
    let g = goldens();
    let tokens = find(&g, "tokens").unwrap().as_i32();
    let fast = session.prefill(&tokens[..16], 1).unwrap();
    session.literal_path = true;
    let slow = session.prefill(&tokens[..16], 1).unwrap();
    assert_eq!(fast.logits.as_f32(), slow.logits.as_f32(),
               "execute_b and execute must be bitwise identical");
}

#[test]
fn compile_cache_reuses_executables() {
    let rt = rt();
    let (_, t_first) = rt.load("tiny.decode_step.b1").unwrap();
    assert!(t_first > 0.0);
    // second load must hit the cache and report the original compile time
    let (_, t_second) = rt.load("tiny.decode_step.b1").unwrap();
    assert_eq!(t_first, t_second);
    assert!(rt.loaded_count() >= 1);
}

#[test]
fn missing_executable_is_clean_error() {
    let rt = rt();
    let err = match rt.load("tiny.nope.b9") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("load of missing executable succeeded"),
    };
    assert!(err.contains("not in manifest"), "{err}");
}

#[test]
fn corrupt_artifact_fails_compile_not_panic() {
    // failure injection: write a garbage HLO file and point a fake spec at
    // it via a scratch manifest dir
    let dir = std::env::temp_dir().join("m2_corrupt_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("hlo")).unwrap();
    // minimal manifest with one bogus executable and no configs
    std::fs::write(dir.join("manifest.json"), r#"{
      "batch_cap": 1, "prefill_buckets": [16], "decode_loop_buckets": [16],
      "forward_buckets": [16], "train_seq_buckets": [],
      "configs": {}, "executables": [{
        "name": "bogus", "file": "hlo/bogus.hlo.txt", "config": "x",
        "entrypoint": "prefill", "n_params": 0, "n_args": 0, "args": [],
        "cost": {}, "memory": {}
      }]}"#).unwrap();
    std::fs::write(dir.join("hlo/bogus.hlo.txt"), "NOT AN HLO MODULE").unwrap();
    let rt = Runtime::new(&dir).unwrap();
    assert!(matches!(rt.load("bogus"), Err(_)));
}
