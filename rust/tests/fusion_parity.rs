//! Fused-vs-unfused bitwise parity (PR 9, DESIGN.md §12).
//!
//! The fusion-region pass is a pure *schedule* transform: regions
//! execute their members as one row-interleaved loop, with single-use
//! intermediates backed by one scratch row instead of full buffers —
//! but every member row body is the exact r-th iteration of the
//! standalone op's scalar loop, so the fused plan must equal the
//! unfused plan **bitwise**, on every entrypoint, worker count, weight
//! precision and kernel tier. No tolerances anywhere in this file.
//!
//! The oracle is the same backend with the pass disabled
//! (`with_fuse(FuseMode::Off)` — what `--fuse off` / `M2_FUSE=off`
//! select; the env spelling itself is covered by
//! `tests/runtime_options_env.rs`, since set_var is not thread-safe
//! under cargo's parallel harness).

use mamba2_serve::runtime::{argmax_last, Backend, CacheState, FuseMode,
                            PlanMode, ReferenceBackend, WeightsDtype};
use mamba2_serve::tensor::kernels::Isa;

fn backend(threads: usize, weights: WeightsDtype, isa: Isa,
           fuse: FuseMode) -> ReferenceBackend {
    ReferenceBackend::seeded("tiny", 0).unwrap()
        .with_threads(threads)
        .with_plan_mode(PlanMode::On)
        .with_weights_dtype(weights)
        .with_isa(isa)
        .with_fuse(fuse)
}

fn prompt(len: usize, salt: usize) -> Vec<i32> {
    (0..len).map(|i| ((i * 41 + 13 * salt + 3) % 512) as i32).collect()
}

/// The kernel tiers to sweep: the scalar baseline always, plus the best
/// tier this host actually has (on a scalar-only host the sweep
/// degenerates to scalar twice, which still runs rather than skips).
fn tiers() -> Vec<Isa> {
    let mut v = vec![Isa::Scalar];
    if Isa::detect() != Isa::Scalar {
        v.push(Isa::detect());
    }
    v
}

#[test]
fn prefill_and_continuation_parity_across_the_knob_matrix() {
    for &threads in &[1usize, 4] {
        for &weights in &[WeightsDtype::F32, WeightsDtype::Bf16] {
            for isa in tiers() {
                let fused = backend(threads, weights, isa, FuseMode::On);
                let plain = backend(threads, weights, isa, FuseMode::Off);
                let tag = format!("threads={threads} \
                                   weights={} isa={}",
                                  weights.as_str(), isa.label());
                for &t in &[16usize, 64] {
                    for &batch in &[1usize, 2] {
                        let toks: Vec<i32> = (0..batch)
                            .flat_map(|b| prompt(t, b + 1))
                            .collect();
                        let a = fused.prefill(&toks, batch).unwrap();
                        let b = plain.prefill(&toks, batch).unwrap();
                        assert_eq!(a.logits.as_f32(), b.logits.as_f32(),
                                   "{tag} t={t} b={batch}: logits");
                        assert_eq!(a.cache.ssm.as_f32(),
                                   b.cache.ssm.as_f32(),
                                   "{tag} t={t} b={batch}: ssm");
                        assert_eq!(a.cache.conv.as_f32(),
                                   b.cache.conv.as_f32(),
                                   "{tag} t={t} b={batch}: conv");
                    }
                }
                // continuation reuses the same plan + slab with cache
                // seeds flowing through — the elided scratch rows must
                // not leak state between rows or calls
                let toks = prompt(48, 7);
                let a1 = fused.prefill(&toks[..16], 1).unwrap();
                let b1 = plain.prefill(&toks[..16], 1).unwrap();
                let a2 = fused.prefill_continue(&a1.cache, &toks[16..], 1)
                    .unwrap();
                let b2 = plain.prefill_continue(&b1.cache, &toks[16..], 1)
                    .unwrap();
                assert_eq!(a2.logits.as_f32(), b2.logits.as_f32(),
                           "{tag}: continuation logits");
                assert_eq!(a2.cache.ssm.as_f32(), b2.cache.ssm.as_f32(),
                           "{tag}: continuation ssm");
            }
        }
    }
}

#[test]
fn decode_parity_across_widths_and_the_knob_matrix() {
    for &threads in &[1usize, 4] {
        for &weights in &[WeightsDtype::F32, WeightsDtype::Bf16] {
            for isa in tiers() {
                let fused = backend(threads, weights, isa, FuseMode::On);
                let plain = backend(threads, weights, isa, FuseMode::Off);
                let tag = format!("threads={threads} weights={} isa={}",
                                  weights.as_str(), isa.label());
                for &bsz in &[1usize, 3, 8] {
                    let mut cache = CacheState::zeros(fused.cfg(), bsz);
                    for s in 0..bsz {
                        let (c, _) = fused
                            .prefill_any(&prompt(16 + 16 * (s % 2), s))
                            .unwrap();
                        cache.copy_slot_from(s, &c, 0);
                    }
                    let toks: Vec<i32> = (0..bsz)
                        .map(|i| ((i * 29 + 5) % 512) as i32).collect();
                    let a = fused.decode_step(&cache, &toks).unwrap();
                    let b = plain.decode_step(&cache, &toks).unwrap();
                    assert_eq!(a.logits.as_f32(), b.logits.as_f32(),
                               "{tag} B={bsz}: logits");
                    assert_eq!(a.cache.ssm.as_f32(), b.cache.ssm.as_f32(),
                               "{tag} B={bsz}: ssm");
                    assert_eq!(a.cache.conv.as_f32(),
                               b.cache.conv.as_f32(),
                               "{tag} B={bsz}: conv");
                }
                // a greedy decode chain keeps the identity step over step
                let (cache, last) =
                    fused.prefill_any(&prompt(32, 9)).unwrap();
                let first = argmax_last(&last)[0];
                let (ga, _) = fused.decode_loop(&cache, first, 12).unwrap();
                let (gb, _) = plain.decode_loop(&cache, first, 12).unwrap();
                assert_eq!(ga, gb, "{tag}: greedy generations diverged");
            }
        }
    }
}

#[test]
fn decode_b1_dump_shows_cost_chosen_regions() {
    // the acceptance shape: bandwidth-bound decode at B=1 fuses nearly
    // end-to-end (≥3 regions on sim-130m), and the off switch really
    // reaches the planner — same backend, no regions, no region tokens
    let on = ReferenceBackend::seeded("sim-130m", 0).unwrap()
        .with_threads(8)
        .with_isa(Isa::Scalar)
        .with_fuse(FuseMode::On)
        .with_plan_mode(PlanMode::On);
    let dump = on.plan_dump("decode_step", 1, 1).expect("planned dump");
    let regions = dump.lines()
        .filter(|l| l.contains(" region="))
        .filter_map(|l| l.split(" region=").nth(1))
        .filter_map(|s| s.split_whitespace().next())
        .collect::<std::collections::BTreeSet<_>>();
    assert!(regions.len() >= 3,
            "decode B=1 should fuse at least 3 regions, got \
             {regions:?}\n{dump}");
    assert!(dump.contains(&format!(" regions={} ", regions.len())),
            "schedule line counts the regions\n{dump}");

    let off = on.with_fuse(FuseMode::Off);
    let dump = off.plan_dump("decode_step", 1, 1).expect("planned dump");
    assert!(dump.contains(" regions=0 "), "off = zero regions\n{dump}");
    assert!(!dump.contains(" region="), "off = no member tokens\n{dump}");
}
