//! Integration: the pure-Rust reference backend against itself — the
//! hermetic analogue of integration_runtime.rs (which needs XLA
//! artifacts).
//!
//! The load-bearing claim is the paper's O(1)-cache exactness: chunked
//! prefill + cached single-token decode must reproduce the non-cached
//! full forward to float32 rounding (Table 6 tolerances, mirroring
//! python/tests/test_kernels.py), and cache slots must survive the
//! copy/restore traffic continuous batching performs.

use mamba2_serve::coordinator::SingleStream;
use mamba2_serve::runtime::{argmax_last, Backend, CacheState,
                            ReferenceBackend};

fn backend() -> ReferenceBackend {
    ReferenceBackend::seeded("tiny", 0).unwrap()
}

fn prompt32() -> Vec<i32> {
    // deterministic pseudo-text over the tiny vocab
    (0..32).map(|i| ((i * 37 + 11) % 512) as i32).collect()
}

#[test]
fn decode_step_chain_matches_forward_full() {
    // the O(1) cache is exact: prefill(16) + 16 steps == forward_full(32),
    // position by position, within the paper's 1e-4 logit tolerance
    let b = backend();
    let tokens = prompt32();
    let full = b.forward_full(&tokens).unwrap();
    let v = *full.dims.last().unwrap() as usize;
    let fv = full.as_f32();

    let pre = b.prefill(&tokens[..16], 1).unwrap();
    // prefilled positions must match the full forward too
    let pv = pre.logits.as_f32();
    for pos in 0..16 {
        let d = fv[pos * v..(pos + 1) * v].iter()
            .zip(&pv[pos * v..(pos + 1) * v])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(d < 1e-4, "prefill pos {pos} diff {d}");
    }
    let mut cache = pre.cache;
    for (i, &tok) in tokens.iter().enumerate().skip(16) {
        let step = b.decode_step(&cache, &[tok]).unwrap();
        cache = step.cache;
        if i + 1 < tokens.len() {
            let row_full = &fv[i * v..(i + 1) * v];
            let row_step = step.logits.as_f32();
            let d = row_full.iter().zip(&row_step)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(d < 1e-4, "pos {i} diff {d}");
        }
    }
}

#[test]
fn prefill_any_matches_bucket_plus_steps() {
    // prefill_any(23 tokens) = prefill(16) + 7 exact steps; final logits
    // must agree with the last row of forward_full over a bucket we have
    let b = backend();
    let tokens: Vec<i32> = prompt32()[..23].to_vec();
    let (cache, last) = b.prefill_any(&tokens).unwrap();
    assert_eq!(cache.batch(), 1);
    // replay manually
    let pre = b.prefill(&tokens[..16], 1).unwrap();
    let mut c2 = pre.cache;
    let mut l2 = None;
    for pos in 16..23 {
        let s = b.decode_step(&c2, &tokens[pos..=pos]).unwrap();
        c2 = s.cache;
        l2 = Some(s.logits);
    }
    assert_eq!(last.as_f32(), l2.unwrap().as_f32(),
               "prefill_any must equal its own policy bitwise");
    assert_eq!(cache.ssm.as_f32(), c2.ssm.as_f32());
    assert_eq!(cache.conv.as_f32(), c2.conv.as_f32());
}

#[test]
fn cached_decode_strategies_agree() {
    // scan-loop and host-loop greedy decode produce identical tokens
    // (paper §3.3 claim, here on the reference backend)
    let b = backend();
    let tokens = prompt32();
    let ss = SingleStream::new(&b);
    let scan = ss.generate_scan(&tokens, 16).unwrap();
    let host = ss.generate_host(&tokens, 16).unwrap();
    assert_eq!(scan, host);
    assert_eq!(scan.len(), 16);
}

#[test]
fn noncached_agrees_on_bucket_boundary() {
    // at context lengths that hit forward buckets exactly, the non-cached
    // baseline's next token equals the cached path's next token
    let b = backend();
    let prompt: Vec<i32> = prompt32()[..16].to_vec();
    let ss = SingleStream::new(&b);
    let host = ss.generate_host(&prompt, 1).unwrap();
    let nc = ss.generate_noncached(&prompt, 1).unwrap();
    assert_eq!(host[0], nc[0]);
}

#[test]
fn cache_slot_copy_restore_round_trip() {
    // continuous-batching traffic: prefill a sequence, copy its slot into
    // a batched cache, decode there, copy back out — identical to never
    // having moved (the slot ops are exact byte moves)
    let b = backend();
    let tokens = prompt32();
    let (cache1, last) = b.prefill_any(&tokens[..16]).unwrap();
    let next = argmax_last(&last)[0];

    // single-slot path
    let s_single = b.decode_step(&cache1, &[next]).unwrap();

    // batched path: install into slot 2 of a 4-wide cache
    let mut batched = CacheState::zeros(b.cfg(), 4);
    batched.copy_slot_from(2, &cache1, 0);
    let s_batch = b.decode_step(&batched, &[0, 0, next, 0]).unwrap();
    let v = b.cfg().vocab_size;
    let row = &s_batch.logits.as_f32()[2 * v..3 * v];
    assert_eq!(row, &s_single.logits.as_f32()[..],
               "slot 2 must decode exactly like the lone sequence");

    // restore: copy slot 2 back out into a batch-1 cache and compare to
    // the single-path cache after the same step
    let mut restored = CacheState::zeros(b.cfg(), 1);
    restored.copy_slot_from(0, &s_batch.cache, 2);
    assert_eq!(restored.ssm.as_f32(), s_single.cache.ssm.as_f32());
    assert_eq!(restored.conv.as_f32(), s_single.cache.conv.as_f32());

    // clearing the slot zeroes exactly that slot
    let mut cleared = s_batch.cache.clone();
    cleared.clear_slot(2);
    let per: usize = cleared.ssm.dims[2..].iter()
        .product::<i64>() as usize;
    let f = cleared.ssm.as_f32();
    for layer in 0..b.cfg().n_layer {
        let base = (layer * 4 + 2) * per;
        assert!(f[base..base + per].iter().all(|&x| x == 0.0));
    }
}

#[test]
fn cache_is_constant_size() {
    // paper Fig. 3: cache bytes do not depend on sequence length
    let b = backend();
    let c1 = CacheState::zeros(b.cfg(), 1);
    assert_eq!(c1.nbytes() as u64, b.cfg().cache_bytes_per_seq());
    let (c16, _) = b.prefill_any(&prompt32()[..16]).unwrap();
    let (c32, _) = b.prefill_any(&prompt32()).unwrap();
    assert_eq!(c16.nbytes(), c32.nbytes());
    assert_eq!(c16.nbytes(), c1.nbytes());
}

#[test]
fn weights_survive_checkpoint_round_trip() {
    // export → rebuild must reproduce logits bitwise (the .mbt path the
    // server's --checkpoint flag uses)
    let a = backend();
    let mut b2 = ReferenceBackend::seeded("tiny", 999).unwrap();
    let tokens = prompt32();
    let la = a.forward_full(&tokens).unwrap();
    assert_ne!(la.as_f32(),
               b2.forward_full(&tokens).unwrap().as_f32(),
               "different seeds must differ");
    b2.load_weights(a.params_host.clone()).unwrap();
    assert_eq!(la.as_f32(), b2.forward_full(&tokens).unwrap().as_f32());
}

#[test]
fn larger_sim_config_also_exact() {
    // the parity property is config-independent; spot-check one step of
    // the next ladder rung
    let b = ReferenceBackend::seeded("sim-130m", 0).unwrap();
    let tokens: Vec<i32> = (0..32).map(|i| ((i * 13 + 5) % 512) as i32)
        .collect();
    let full = b.forward_full(&tokens).unwrap();
    let v = *full.dims.last().unwrap() as usize;
    let fv = full.as_f32();
    let pre = b.prefill(&tokens[..16], 1).unwrap();
    let step = b.decode_step(&pre.cache, &[tokens[16]]).unwrap();
    let d = fv[16 * v..17 * v].iter().zip(&step.logits.as_f32())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(d < 1e-4, "sim-130m step diff {d}");
}
