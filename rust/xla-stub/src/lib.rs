//! Compile-only stub of the `xla` crate API surface that
//! `mamba2-serve --features xla` consumes (see `rust/Cargo.toml`).
//!
//! Exactly the types and signatures used by `runtime/session.rs` and
//! `tensor/mod.rs`, with every fallible entry point returning a clean
//! "stub" error at runtime and no native code anywhere. This keeps the
//! whole xla-gated path *compiling* in the hermetic environment — CI's
//! `cargo check --features xla --all-targets` — so the feature can't
//! silently rot, while the real backend still requires swapping in the
//! actual binding (github.com/LaurentMazare/xla-rs) plus its
//! `xla_extension` native library.

use std::fmt;
use std::path::Path;

/// Error for every stub entry point.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} is compile-only — swap rust/xla-stub for the \
         real `xla` crate (see rust/Cargo.toml) to execute the XLA \
         backend"
    )))
}

/// Element types the binding can move across the host/device boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Host element types `Literal::vec1` / `Literal::to_vec` accept.
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host literal (stub: carries nothing).
#[derive(Debug, Clone)]
pub struct Literal {}

impl Literal {
    pub fn vec1<T: NativeType>(_vals: &[T]) -> Literal {
        Literal {}
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub("Literal::reshape")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        stub("Literal::array_shape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        stub("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        stub("Literal::to_tuple")
    }
}

#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P)
        -> Result<HloModuleProto> {
        stub("HloModuleProto::from_text_file")
    }
}

#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

#[derive(Debug)]
pub struct PjRtDevice {}

#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L])
        -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer])
        -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute_b")
    }
}

#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation)
        -> Result<PjRtLoadedExecutable> {
        stub("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(&self, _device: Option<&PjRtDevice>,
                                    _lit: &Literal) -> Result<PjRtBuffer> {
        stub("PjRtClient::buffer_from_host_literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_errors_cleanly() {
        assert!(PjRtClient::cpu().unwrap_err().to_string()
            .contains("xla stub"));
        assert!(HloModuleProto::from_text_file("/nope").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
        assert_eq!(<i32 as NativeType>::TY, ElementType::S32);
    }
}
