//! Perf-trajectory harness: the repo's cross-PR performance trail.
//!
//! Measures the two hot paths of the serving stack on the active backend
//! and emits the schema-pinned `BENCH_<tag>.json` (see
//! `bench_support::validate_trajectory_json` and README §Benchmarks):
//!
//!   * batch-fused decode: tokens/s at B ∈ {1, 4, 16} from realistic
//!     (prefilled) cache slots — the B=16/B=1 ratio is the structural
//!     check that batching actually fuses (weights read once per launch,
//!     matmul row blocks across the threadpool), and CI's `perf-smoke`
//!     job fails if it drops below 2×,
//!   * chunked-parallel prefill: tokens/s at L ∈ {512, 2048}, plus
//!     analytic MFU/HBU against the host-CPU roofline,
//!   * the plan cache (schema 1.1): plans built, cache hits and total
//!     planning time across the whole run — "build plan once, execute
//!     many" made measurable (zero block on planner-less backends).
//!
//! `--quick` trims the measurement protocol for CI smoke runs (the sweep
//! itself is never trimmed — the schema pins it). `--check` exits
//! non-zero when the batched-decode speedup misses the gate
//! (`--min-speedup X` overrides the 2.0 default).

use mamba2_serve::bench_support::{batch_speedup, decode_point,
                                  open_backend, prefill_point, quick,
                                  trajectory_json, write_trajectory,
                                  DecodePoint, PrefillPoint};
use mamba2_serve::runtime::{reference, Backend, CacheState};
use mamba2_serve::util::benchkit::{Bench, Table};

const TAG: &str = "pr4";
const MODEL: &str = "sim-130m";
const DECODE_BATCHES: [usize; 3] = [1, 4, 16];
const PREFILL_LENS: [usize; 2] = [512, 2048];

fn arg_after(flag: &str) -> Option<String> {
    let mut args = std::env::args();
    while let Some(a) = args.next() {
        if a == flag {
            return args.next();
        }
    }
    None
}

fn main() {
    let check = std::env::args().any(|a| a == "--check");
    let min_speedup: f64 = arg_after("--min-speedup")
        .and_then(|v| v.parse().ok())
        .unwrap_or(2.0);
    let session = open_backend(MODEL);
    let threads = reference::default_threads();
    let mut bench = Bench::new().quiet();

    // ---- decode sweep: one prefilled sequence broadcast to B slots ----
    let prompt: Vec<i32> = (0..32).map(|i| ((i * 37 + 11) % 512) as i32)
        .collect();
    let (seed_cache, _) = session.prefill_any(&prompt).unwrap();
    let mut decode: Vec<DecodePoint> = Vec::new();
    for &b in &DECODE_BATCHES {
        let mut cache = CacheState::zeros(session.cfg(), b);
        for s in 0..b {
            cache.copy_slot_from(s, &seed_cache, 0);
        }
        let tokens: Vec<i32> =
            (0..b as i32).map(|i| (i * 7 + 3) % 512).collect();
        let m = bench.measure(&format!("decode.b{b}"), b as f64, || {
            session.decode_step(&cache, &tokens).unwrap();
        });
        decode.push(decode_point(&session.cost("decode_step", None, b),
                                 b, m.summary.mean));
        eprintln!("  decode B={b}: {:.2} ms/step, {:.1} tok/s",
                  m.summary.mean * 1e3, b as f64 / m.summary.mean);
    }

    // ---- prefill sweep --------------------------------------------------
    let mut prefill: Vec<PrefillPoint> = Vec::new();
    for &l in &PREFILL_LENS {
        let tokens: Vec<i32> = (0..l).map(|i| ((i * 37 + 11) % 512) as i32)
            .collect();
        let m = bench.measure(&format!("prefill.t{l}"), l as f64, || {
            session.prefill(&tokens, 1).unwrap();
        });
        prefill.push(prefill_point(&session.cost("prefill", Some(l), 1),
                                   l, m.summary.mean));
        eprintln!("  prefill L={l}: {:.1} ms, {:.0} tok/s",
                  m.summary.mean * 1e3, l as f64 / m.summary.mean);
    }

    // ---- human table + machine-readable trajectory ----------------------
    let mut td = Table::new(
        &format!("Perf trajectory {TAG} — batch-fused decode \
                  ({MODEL}, {} ({}), {threads} threads)",
                 session.name(), session.platform()),
        &["B", "ms/step", "tok/s", "MFU %", "HBU %"]);
    for p in &decode {
        td.row(vec![p.batch.to_string(),
                    format!("{:.3}", p.ms_per_step),
                    format!("{:.1}", p.tokens_per_s),
                    format!("{:.2}", p.mfu * 100.0),
                    format!("{:.2}", p.hbu * 100.0)]);
    }
    td.print();
    let mut tp = Table::new(
        &format!("Perf trajectory {TAG} — chunked-parallel prefill"),
        &["L", "ms", "tok/s", "MFU %", "HBU %"]);
    for p in &prefill {
        tp.row(vec![p.seq_len.to_string(),
                    format!("{:.1}", p.ms_total),
                    format!("{:.0}", p.tokens_per_s),
                    format!("{:.2}", p.mfu * 100.0),
                    format!("{:.2}", p.hbu * 100.0)]);
    }
    tp.print();

    let plan_stats = session.plan_stats();
    if let Some(ps) = plan_stats {
        eprintln!("  plan cache: {} built, {} hits, {:.2} ms planning",
                  ps.built, ps.hits, ps.planning_ms);
    }
    let doc = trajectory_json(TAG, MODEL, session.name(), threads, quick(),
                              &decode, &prefill, plan_stats);
    let path = write_trajectory(TAG, &doc).unwrap_or_else(|e| {
        eprintln!("cannot write trajectory: {e}");
        std::process::exit(1);
    });
    let speedup = batch_speedup(&decode);
    println!("wrote {} (batched decode B=16 vs B=1: {speedup:.2}x)",
             path.display());

    if check && speedup < min_speedup {
        eprintln!("FAIL: batched decode speedup {speedup:.2}x < \
                   {min_speedup:.2}x gate — batching is not fusing");
        std::process::exit(1);
    }
}
