//! Perf-trajectory harness: the repo's cross-PR performance trail.
//!
//! Measures the two hot paths of the serving stack on the active backend
//! and emits the schema-pinned `BENCH_<tag>.json` (see
//! `bench_support::validate_trajectory_json` and README §Benchmarks):
//!
//!   * batch-fused decode: tokens/s at B ∈ {1, 4, 16} from realistic
//!     (prefilled) cache slots, **at every weight precision** (schema
//!     1.2/1.7): the f32 rows are the cross-PR comparable baseline,
//!     the bf16 rows are the precision pass's headline — halved
//!     `bytes_streamed_per_token`, and tokens/s that must beat f32 at
//!     B = 1 (the pass exists because decode is bandwidth-bound) —
//!     and the int8/q4 rows (schema 1.7) measure the group-quantised
//!     streams of DESIGN.md §13, whose B=1 byte models must order
//!     strictly `q4 < int8 < bf16 < f32`,
//!   * chunked-parallel prefill: tokens/s at L ∈ {512, 2048}, plus
//!     analytic MFU/HBU against the host-CPU roofline — **per kernel
//!     tier** (schema 1.5): the scalar rows are the cross-PR baseline,
//!     and when the host has a vector unit a second row set measures
//!     the planner's re-tiered prefill (DESIGN.md §11),
//!   * the plan cache: plans built, cache hits and total planning time
//!     across the two measured sessions (zero block on planner-less
//!     backends),
//!   * the prompt-prefix cache (schema 1.3): hits, misses and resident
//!     bytes from replaying a shared-prefix workload through an engine
//!     replica — the serving-side economics of O(1) state (DESIGN.md
//!     §9),
//!   * the HTTP gateway (schema 1.4): completions admitted and shed by
//!     driving `/v1/completions` against a live one-replica pool — the
//!     serving surface measured end-to-end (DESIGN.md §10),
//!   * the fusion-region pass (schema 1.6): every row counts its
//!     plan's cost-chosen regions, the top-level `fusion` block totals
//!     regions planned and bytes elided, and a second B=1 decode
//!     backend opened under `M2_FUSE=off` anchors the streamed-bytes
//!     comparison (DESIGN.md §12).
//!
//! `--quick` trims the measurement protocol for CI smoke runs (the sweep
//! itself is never trimmed — the schema pins it). `--check` exits
//! non-zero when a structural gate misses:
//!
//!   * f32 decode B=16 tok/s ≥ 2× B=1 (`--min-speedup X` overrides),
//!   * prefill L=2048 tok/s ≥ the same multiple of f32 B=1 decode
//!     tok/s (the prefill fan-out analogue of the fusion gate),
//!   * bf16 decode B=1 tok/s > f32 B=1 tok/s (skipped when the backend
//!     has no precision pass, e.g. XLA),
//!   * vector-tier prefill L=2048 tok/s ≥ the scalar tier's (the
//!     planner only re-tiers nodes its pricing says win, so losing is
//!     a pricing bug — skipped with a notice on scalar-only hosts),
//!   * fusion-on decode B=1 `bytes_streamed_per_token` ≤ fusion-off
//!     (schema 1.6): the region pass only fuses where its byte model
//!     says DRAM traffic drops, so streaming *more* with the pass on
//!     is a costing bug — skipped when the backend has no planner,
//!   * the quantised byte models order strictly (schema 1.7,
//!     `quant_bytes_ordering`): at B=1 every reduced dtype measured
//!     must stream fewer bytes per token than the next wider one —
//!     skipped only when no quantised rows exist.
//!
//! `--baseline <BENCH_*.json>` additionally gates the f32 decode rows
//! against a previous PR's artifact (fail on a >10% tok/s drop;
//! incomparable baselines are reported and skipped).

use std::sync::Arc;
use std::time::Duration;

use mamba2_serve::bench_support::{batch_speedup, compare_to_baseline,
                                  decode_point, dtype_speedup,
                                  isa_prefill_speedup, open_backend,
                                  prefill_point, quant_bytes_ordering,
                                  quick, trajectory_json,
                                  write_trajectory, BaselineCheck,
                                  DecodePoint, FusionSummary,
                                  GatewayTraffic, PrefillPoint};
use mamba2_serve::coordinator::{Engine, EngineConfig, GenerateParams,
                                PrefixCacheStats};
use mamba2_serve::eval::{corpus, Tokenizer};
use mamba2_serve::gateway::http::http_roundtrip;
use mamba2_serve::gateway::pool::{self, PoolConfig};
use mamba2_serve::gateway::{Gateway, GatewayConfig};
use mamba2_serve::runtime::{reference, Backend, CacheState, PlanStats};
use mamba2_serve::util::benchkit::{Bench, Table};
use mamba2_serve::util::json::Json;

const TAG: &str = "pr10";
const MODEL: &str = "sim-130m";
const DECODE_BATCHES: [usize; 3] = [1, 4, 16];
const PREFILL_LENS: [usize; 2] = [512, 2048];

fn arg_after(flag: &str) -> Option<String> {
    let mut args = std::env::args();
    while let Some(a) = args.next() {
        if a == flag {
            return args.next();
        }
    }
    None
}

/// Decode sweep over one backend: B ∈ {1, 4, 16} from prefilled slots.
fn decode_sweep(session: &dyn Backend, bench: &mut Bench,
                out: &mut Vec<DecodePoint>,
                fusion: &mut FusionSummary) {
    let dt = session.weights_dtype();
    let prompt: Vec<i32> = (0..32).map(|i| ((i * 37 + 11) % 512) as i32)
        .collect();
    let (seed_cache, _) = session.prefill_any(&prompt).unwrap();
    for &b in &DECODE_BATCHES {
        let mut cache = CacheState::zeros(session.cfg(), b);
        for s in 0..b {
            cache.copy_slot_from(s, &seed_cache, 0);
        }
        let tokens: Vec<i32> =
            (0..b as i32).map(|i| (i * 7 + 3) % 512).collect();
        let m = bench.measure(&format!("decode.{dt}.b{b}"), b as f64,
                              || {
            session.decode_step(&cache, &tokens).unwrap();
        });
        // the decode plan is warm after the measurement, so the byte
        // model and the fusion counters answer from the plan (halved
        // weights under bf16)
        let fstats = session.fusion_stats("decode_step", None, b);
        fusion.add(fstats);
        out.push(decode_point(&session.cost("decode_step", None, b), b,
                              m.summary.mean, dt,
                              session.bytes_streamed_per_token(b),
                              session.isa(), fstats.0));
        eprintln!("  decode[{dt}] B={b}: {:.2} ms/step, {:.1} tok/s, \
                   {:.0} B/tok, {} fused regions",
                  m.summary.mean * 1e3, b as f64 / m.summary.mean,
                  session.bytes_streamed_per_token(b), fstats.0);
    }
}

fn main() {
    let check = std::env::args().any(|a| a == "--check");
    let min_speedup: f64 = arg_after("--min-speedup")
        .and_then(|v| v.parse().ok())
        .unwrap_or(2.0);
    let baseline_path = arg_after("--baseline");
    // the sweep owns the dtype and ISA knobs: the scalar f32 rows are
    // mandatory (the schema's cross-PR baseline), whatever the
    // inherited env says
    std::env::set_var("M2_WEIGHTS", "f32");
    std::env::set_var("M2_ISA", "scalar");
    let session = open_backend(MODEL);
    let threads = reference::default_threads();
    let mut bench = Bench::new().quiet();

    // ---- decode sweeps: f32 baseline, then each reduced weight stream
    // (bf16, then the schema-1.7 group-quantised int8/q4) ----
    let mut decode: Vec<DecodePoint> = Vec::new();
    let mut fusion = FusionSummary::default();
    decode_sweep(session.as_ref(), &mut bench, &mut decode, &mut fusion);
    let mut reduced_sessions = Vec::new();
    for dt in ["bf16", "int8", "q4"] {
        std::env::set_var("M2_WEIGHTS", dt);
        let s = open_backend(MODEL);
        std::env::set_var("M2_WEIGHTS", "f32");
        if s.weights_dtype() == dt {
            decode_sweep(s.as_ref(), &mut bench, &mut decode,
                         &mut fusion);
            reduced_sessions.push(s);
        } else {
            eprintln!("  backend {} has no {dt} weight stream — rows \
                       skipped", s.name());
        }
    }
    let has_bf16 = decode.iter().any(|p| p.weights_dtype == "bf16");

    // ---- prefill sweep (always f32: the pass is decode-only) --------
    // Scalar-tier rows first (the cross-PR baseline); when the host has
    // a vector unit, a second backend opened under M2_ISA=auto measures
    // the planner's re-tiered prefill rides along (schema 1.5 tags
    // every row with its effective tier).
    let mut prefill: Vec<PrefillPoint> = Vec::new();
    let mut prefill_sweep = |session: &dyn Backend,
                             prefill: &mut Vec<PrefillPoint>,
                             fusion: &mut FusionSummary| {
        let isa = session.isa();
        for &l in &PREFILL_LENS {
            let tokens: Vec<i32> =
                (0..l).map(|i| ((i * 37 + 11) % 512) as i32).collect();
            let m = bench.measure(&format!("prefill.{isa}.t{l}"),
                                  l as f64, || {
                session.prefill(&tokens, 1).unwrap();
            });
            let fstats = session.fusion_stats("prefill", Some(l), 1);
            fusion.add(fstats);
            prefill.push(prefill_point(
                &session.cost("prefill", Some(l), 1), l, m.summary.mean,
                isa, fstats.0));
            eprintln!("  prefill[{isa}] L={l}: {:.1} ms, {:.0} tok/s, \
                       {} fused regions",
                      m.summary.mean * 1e3, l as f64 / m.summary.mean,
                      fstats.0);
        }
    };
    prefill_sweep(session.as_ref(), &mut prefill, &mut fusion);
    std::env::set_var("M2_ISA", "auto");
    let session_vec = open_backend(MODEL);
    std::env::set_var("M2_ISA", "scalar");
    let vec_isa = session_vec.isa();
    let has_vector = vec_isa != "scalar";
    if has_vector {
        prefill_sweep(session_vec.as_ref(), &mut prefill, &mut fusion);
    } else {
        eprintln!("  backend {} has no vector kernel tier on this host \
                   — scalar prefill rows only", session_vec.name());
    }

    // ---- fusion-off anchor for the streamed-bytes gate (1.6) ------------
    // A second backend opened under M2_FUSE=off plans the same B=1
    // decode without the region pass; one step warms its plan so the
    // byte model answers from it. The pass only fuses where its byte
    // model says DRAM traffic drops, so fused must stream ≤ unfused.
    std::env::set_var("M2_FUSE", "off");
    let session_off = open_backend(MODEL);
    std::env::set_var("M2_FUSE", "on");
    let has_fusion = session.fusion_stats("decode_step", None, 1).0 > 0;
    let (on_bpt, off_bpt) = if has_fusion {
        let prompt: Vec<i32> = (0..32)
            .map(|i| ((i * 37 + 11) % 512) as i32).collect();
        let (c, _) = session_off.prefill_any(&prompt).unwrap();
        let mut cache = CacheState::zeros(session_off.cfg(), 1);
        cache.copy_slot_from(0, &c, 0);
        session_off.decode_step(&cache, &[3]).unwrap();
        (session.bytes_streamed_per_token(1),
         session_off.bytes_streamed_per_token(1))
    } else {
        (0.0, 0.0)
    };
    if has_fusion {
        eprintln!("  fusion: {} regions planned, {:.0} B elided across \
                   the measured plans; decode B=1 streams {on_bpt:.0} \
                   B/tok fused vs {off_bpt:.0} unfused",
                  fusion.regions_planned, fusion.bytes_elided);
    } else {
        eprintln!("  backend {} plans no fusion regions — zero fusion \
                   block", session.name());
    }

    // ---- prefix cache: shared-prefix replay through an engine -----------
    // Eight requests share a 256-token "system prompt"; the engine's
    // prompt-prefix cache (schema 1.3 block) should prefill the shared
    // segment once and seed every later request from the stored state.
    // A fresh backend replica feeds the engine so the sweeps above stay
    // untouched; its plans are deliberately outside the plan_cache block.
    let eng = Engine::start(open_backend(MODEL), EngineConfig {
        prefix_cache_bytes: 16 << 20,
        ..Default::default()
    }).unwrap_or_else(|e| {
        eprintln!("cannot start engine for prefix-cache replay: {e}");
        std::process::exit(1);
    });
    let shared: Vec<i32> = (0..256).map(|i| ((i * 37 + 11) % 512) as i32)
        .collect();
    let mut submitted = 0u64;
    for r in 0..8usize {
        let mut p = shared.clone();
        p.extend((0..8usize).map(|i| ((i * 13 + 7 * r + 5) % 512) as i32));
        submitted += p.len() as u64;
        eng.generate(p, GenerateParams::new().max_new_tokens(4))
            .collect()
            .unwrap_or_else(|e| {
                eprintln!("prefix-cache replay failed: {e}");
                std::process::exit(1);
            });
    }
    let es = eng.metrics.snapshot();
    let prefix_stats = PrefixCacheStats {
        hits: es.prefix_hits,
        misses: es.prefix_misses,
        evictions: es.prefix_evictions,
        insertions: es.prefix_insertions,
        bytes: es.prefix_bytes,
        entries: es.prefix_entries,
    };
    eprintln!("  prefix cache: {} hits / {} misses, {} B resident; \
               prefilled {} of {} submitted prompt tokens",
              prefix_stats.hits, prefix_stats.misses, prefix_stats.bytes,
              es.prefill_tokens, submitted);

    // ---- gateway: HTTP traffic leg (schema 1.4 block) -------------------
    // A one-replica pool behind the OpenAI-compatible gateway, driven
    // with a handful of real HTTP completions — the trajectory records
    // that the serving surface works end-to-end, not its latency (that
    // is serving_throughput's job).
    let (router, _gauge) = pool::build(PoolConfig {
        model: MODEL.into(),
        replicas: 1,
        ..Default::default()
    }).unwrap_or_else(|e| {
        eprintln!("cannot build gateway pool: {e}");
        std::process::exit(1);
    });
    let gw = Gateway::new(
        Arc::clone(&router),
        Arc::new(Tokenizer::train(corpus::BUNDLED, 256)),
        GatewayConfig {
            model: MODEL.into(),
            threads: 2,
            keep_alive: Duration::from_millis(500),
            ..Default::default()
        });
    let handle = gw.start("127.0.0.1:0").unwrap_or_else(|e| {
        eprintln!("cannot start gateway: {e}");
        std::process::exit(1);
    });
    for i in 0..4 {
        let body = format!(
            "{{\"model\":\"{MODEL}\",\"prompt\":\"trajectory leg {i}\",\
             \"max_tokens\":4}}");
        let (status, _, _) = http_roundtrip(
            &handle.addr(), "POST", "/v1/completions", body.as_bytes())
            .unwrap_or_else(|e| {
                eprintln!("gateway completion failed: {e}");
                std::process::exit(1);
            });
        if status != 200 {
            eprintln!("gateway completion returned {status}");
            std::process::exit(1);
        }
    }
    let gw_traffic = GatewayTraffic {
        requests: handle.requests_total(),
        shed: handle.shed_total(),
        replicas: router.n_replicas() as u64,
    };
    eprintln!("  gateway: {} completions admitted, {} shed, {} replica(s)",
              gw_traffic.requests, gw_traffic.shed, gw_traffic.replicas);
    handle.drain().unwrap_or_else(|e| {
        eprintln!("gateway drain failed: {e}");
        std::process::exit(1);
    });

    // ---- human table + machine-readable trajectory ----------------------
    let mut td = Table::new(
        &format!("Perf trajectory {TAG} — batch-fused decode \
                  ({MODEL}, {} ({}), {threads} threads)",
                 session.name(), session.platform()),
        &["B", "weights", "ms/step", "tok/s", "B/tok", "MFU %", "HBU %"]);
    for p in &decode {
        td.row(vec![p.batch.to_string(),
                    p.weights_dtype.clone(),
                    format!("{:.3}", p.ms_per_step),
                    format!("{:.1}", p.tokens_per_s),
                    format!("{:.0}", p.bytes_streamed_per_token),
                    format!("{:.2}", p.mfu * 100.0),
                    format!("{:.2}", p.hbu * 100.0)]);
    }
    td.print();
    let mut tp = Table::new(
        &format!("Perf trajectory {TAG} — chunked-parallel prefill"),
        &["L", "isa", "ms", "tok/s", "MFU %", "HBU %"]);
    for p in &prefill {
        tp.row(vec![p.seq_len.to_string(),
                    p.isa.clone(),
                    format!("{:.1}", p.ms_total),
                    format!("{:.0}", p.tokens_per_s),
                    format!("{:.2}", p.mfu * 100.0),
                    format!("{:.2}", p.hbu * 100.0)]);
    }
    tp.print();

    // the plan_cache block covers the WHOLE run: every measured
    // session's plans (the reduced-dtype and vector-tier sweeps build
    // their own) summed together
    let mut extra_stats: Vec<Option<PlanStats>> = reduced_sessions
        .iter().map(|s| s.plan_stats()).collect();
    if has_vector {
        extra_stats.push(session_vec.plan_stats());
    }
    let plan_stats = extra_stats.into_iter().flatten()
        .fold(session.plan_stats(), |acc, b| match acc {
            Some(a) => Some(PlanStats {
                built: a.built + b.built,
                hits: a.hits + b.hits,
                planning_ms: a.planning_ms + b.planning_ms,
                cached: a.cached + b.cached,
            }),
            None => Some(b),
        });
    if let Some(ps) = plan_stats {
        eprintln!("  plan cache: {} built, {} hits, {:.2} ms planning",
                  ps.built, ps.hits, ps.planning_ms);
    }
    let doc = trajectory_json(TAG, MODEL, session.name(), threads, quick(),
                              &decode, &prefill, plan_stats,
                              Some(prefix_stats), Some(gw_traffic),
                              Some(fusion));
    let path = write_trajectory(TAG, &doc).unwrap_or_else(|e| {
        eprintln!("cannot write trajectory: {e}");
        std::process::exit(1);
    });
    let speedup = batch_speedup(&decode);
    let bf16_ratio = dtype_speedup(&decode, 1);
    let isa_ratio = isa_prefill_speedup(&prefill, 2048, vec_isa);
    let b1_bytes = |dt: &str| decode.iter()
        .find(|p| p.batch == 1 && p.weights_dtype == dt)
        .map(|p| p.bytes_streamed_per_token).unwrap_or(0.0);
    println!("wrote {} (f32 decode B=16 vs B=1: {speedup:.2}x; bf16 vs \
              f32 at B=1: {bf16_ratio:.2}x; {vec_isa} vs scalar \
              prefill at L=2048: {isa_ratio:.2}x; B=1 bytes/tok \
              f32={:.0} bf16={:.0} int8={:.0} q4={:.0})",
             path.display(), b1_bytes("f32"), b1_bytes("bf16"),
             b1_bytes("int8"), b1_bytes("q4"));

    // ---- structural gates (--check) -------------------------------------
    let mut failed = false;
    if check {
        if speedup < min_speedup {
            eprintln!("FAIL: batched decode speedup {speedup:.2}x < \
                       {min_speedup:.2}x gate — batching is not fusing");
            failed = true;
        }
        // prefill analogue of the fusion gate: the fanned-out chunked
        // prefill at L=2048 must clear the same multiple of the
        // single-slot decode rate (both are per-token rates on the
        // same weights, so the ratio is runner-noise-robust)
        let b1_f32 = decode.iter()
            .find(|p| p.batch == 1 && p.weights_dtype == "f32")
            .map(|p| p.tokens_per_s)
            .unwrap_or(0.0);
        let pre2048 = prefill.iter().find(|p| p.seq_len == 2048)
            .map(|p| p.tokens_per_s)
            .unwrap_or(0.0);
        if pre2048 < min_speedup * b1_f32 {
            eprintln!("FAIL: prefill L=2048 at {pre2048:.0} tok/s < \
                       {min_speedup:.1}x the B=1 decode rate \
                       ({b1_f32:.1}) — the chunked path lost its \
                       parallel win");
            failed = true;
        }
        if has_bf16 && bf16_ratio <= 1.0 {
            eprintln!("FAIL: bf16 decode at B=1 is {bf16_ratio:.2}x f32 \
                       — the halved weight stream must pay on the \
                       bandwidth-bound path");
            failed = true;
        }
        // kernel-tier gate (1.5): the planner only re-tiers prefill
        // nodes its pricing says win, so the vector tier losing to
        // scalar at L=2048 is a pricing bug, not noise
        if has_vector {
            if isa_ratio < 1.0 {
                eprintln!("FAIL: {vec_isa} prefill at L=2048 is \
                           {isa_ratio:.2}x scalar — the planner's ISA \
                           re-tiering must not lose to its own \
                           fallback");
                failed = true;
            }
        } else {
            println!("isa gate: skipped — no vector kernel tier on \
                      this host");
        }
        // fusion gate (1.6): with the region pass on, the planned B=1
        // decode must stream no more bytes per token than with it off
        // — fusing is only ever chosen to cut DRAM traffic
        if has_fusion {
            if on_bpt > off_bpt {
                eprintln!("FAIL: fusion-on decode B=1 streams \
                           {on_bpt:.0} B/tok > fusion-off \
                           {off_bpt:.0} — the region pass must never \
                           add DRAM traffic");
                failed = true;
            }
        } else {
            println!("fusion gate: skipped — backend plans no regions");
        }
        // quantised-bytes gate (1.7): whatever reduced dtypes were
        // measured, their B=1 byte models must order strictly — the
        // planner prices the code stream plus the amortised scales,
        // so a tie or inversion is a pricing bug
        if decode.iter().any(|p| matches!(p.weights_dtype.as_str(),
                                          "int8" | "q4")) {
            if let Err(why) = quant_bytes_ordering(&decode) {
                eprintln!("FAIL: {why} — the quantised stream must \
                           shrink the modelled decode bytes");
                failed = true;
            }
        } else {
            println!("quant gate: skipped — no quantised decode rows");
        }
    }

    // ---- perf gate vs the previous PR's artifact ------------------------
    if let Some(bp) = baseline_path {
        let text = std::fs::read_to_string(&bp).unwrap_or_else(|e| {
            eprintln!("cannot read baseline {bp}: {e}");
            std::process::exit(1);
        });
        let old = Json::parse(&text).unwrap_or_else(|e| {
            eprintln!("cannot parse baseline {bp}: {e}");
            std::process::exit(1);
        });
        match compare_to_baseline(&doc, &old, 0.10) {
            BaselineCheck::Skipped(why) => {
                println!("perf gate: baseline {bp} skipped — {why}");
            }
            BaselineCheck::Compared { regressions }
                if regressions.is_empty() => {
                println!("perf gate: no f32 decode regression vs {bp}");
            }
            BaselineCheck::Compared { regressions } => {
                for r in &regressions {
                    eprintln!("FAIL: {r}");
                }
                failed = true;
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
}
