//! Paper Table 2 + Figure 4(a) + Figure 6: prefill model-FLOP utilisation.
//!
//! MFU = (F / t_wall) / peak (paper Eq. 4). The numerator comes from the
//! backend's cost model: the XLA cost analysis recorded in the manifest
//! at AOT time (exactly the paper's F_XLA) on the xla backend, the
//! analytic model over the same config shapes on the reference backend.
//! CPU MFU is measured; TPU-v6e MFU is projected at paper scale.

use mamba2_serve::bench_support::{open_backend, paper_config, quick,
                                  SIM_MODELS};
use mamba2_serve::perf::sim::project_prefill;
use mamba2_serve::perf::{mfu, CPU_HOST, TPU_V6E};
use mamba2_serve::runtime::Backend;
use mamba2_serve::util::benchkit::{save_results, Bench, Table};

/// Paper Table 2 (prefill MFU %, prompt lengths 1024/4096/8192).
const PAPER_T2: [(&str, [f64; 3]); 5] = [
    ("130M", [6.22, 8.23, 7.68]),
    ("370M", [7.47, 9.04, 7.60]),
    ("780M", [10.62, 11.33, 8.20]),
    ("1.3B", [12.53, 11.67, 8.39]),
    ("2.7B", [15.23, 12.96, 9.71]),
];

fn main() {
    let prompts: Vec<usize> = if quick() { vec![64] } else { vec![64, 256, 512] };
    let models: Vec<_> = if quick() { SIM_MODELS[..2].to_vec() }
                         else { SIM_MODELS.to_vec() };

    let mut bench = Bench::new().quiet();
    let mut measured = Table::new(
        "Measured prefill MFU % (CPU; F from the backend's cost model)",
        &["Model", "t=64", "t=256", "t=512", "tokens/s @512"]);

    let mut costs = Vec::new(); // (name, cost) for the shape check below
    for (sim, _) in &models {
        let session = open_backend(sim);
        let mut row = vec![sim.to_string()];
        let mut last_tps = 0.0;
        for &t in &prompts {
            let name = format!("{sim}.prefill.t{t}");
            let cost = session.cost("prefill", Some(t), 1);
            let tokens: Vec<i32> = (0..t as i32).map(|i| i % 512).collect();
            let m = bench.measure(&name, t as f64, || {
                session.prefill(&tokens, 1).unwrap();
            });
            row.push(format!("{:.2}",
                             mfu(&cost, m.summary.mean,
                                 CPU_HOST.peak_tflops) * 100.0));
            last_tps = m.throughput();
            costs.push((name, cost));
        }
        while row.len() < 4 { row.push("-".into()); }
        row.push(format!("{last_tps:.0}"));
        measured.row(row);
        eprintln!("  [{sim}] done");
    }
    measured.print();

    // -------- projection at paper scale vs paper Table 2 -------------
    let mut proj = Table::new(
        "Projected TPU v6e prefill MFU % vs paper Table 2 (batch 1, bf16)",
        &["Model", "proj 1024", "paper 1024", "proj 4096", "paper 4096",
          "proj 8192", "paper 8192"]);
    for (scale, paper_vals) in PAPER_T2 {
        let c = paper_config(scale);
        let mut row = vec![scale.to_string()];
        for (i, &t) in [1024usize, 4096, 8192].iter().enumerate() {
            let p = project_prefill(&c, t, &TPU_V6E, 2.0);
            row.push(format!("{:.2}", p.mfu * 100.0));
            row.push(format!("{:.2}", paper_vals[i]));
        }
        proj.row(row);
    }
    proj.print();

    // shape check: MFU increases with model size (paper Fig. 6)
    let mut shape = Table::new("Shape checks", &["Claim", "Holds"]);
    if !quick() {
        let m_small = bench.get("sim-130m.prefill.t512").unwrap();
        let m_big = bench.get("sim-2.7b.prefill.t512").unwrap();
        let find = |n: &str| {
            costs.iter().find(|c| c.0 == n).unwrap().1.clone()
        };
        let cost_s = find("sim-130m.prefill.t512");
        let cost_b = find("sim-2.7b.prefill.t512");
        let mfu_s = mfu(&cost_s, m_small.summary.mean, CPU_HOST.peak_tflops);
        let mfu_b = mfu(&cost_b, m_big.summary.mean, CPU_HOST.peak_tflops);
        shape.row(vec![
            format!("MFU rises with scale: {:.2}% -> {:.2}%",
                    mfu_s * 100.0, mfu_b * 100.0),
            (mfu_b > mfu_s).to_string(),
        ]);
    }
    shape.print();

    save_results("table2_prefill_mfu", &[&measured, &proj, &shape]);
}
