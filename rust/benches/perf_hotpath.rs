//! §Perf harness: the L3 hot-path levers, measured before/after.
//!
//!   1. params resident on device (`execute_b`) vs re-uploaded as literals
//!      every call — the dominant per-call cost at small model scale
//!   2. compiled decode loop (scan) vs host-driven step loop — launch and
//!      output-roundtrip amortisation
//!   3. batched decode step vs sequential single steps — the continuous
//!      batcher's amortisation of the per-launch cost
//!
//! Results go to EXPERIMENTS.md §Perf.

use mamba2_serve::bench_support::{open_runtime, quick};
use mamba2_serve::coordinator::SingleStream;
use mamba2_serve::runtime::{CacheState, ModelSession};
use mamba2_serve::util::benchkit::{save_results, Bench, Table};

fn main() {
    let rt = open_runtime();
    let models = if quick() { vec!["sim-130m"] }
                 else { vec!["sim-130m", "sim-1.3b"] };
    let mut bench = Bench::new().with_protocol(3, 7).quiet();
    let mut t = Table::new(
        "§Perf: hot-path levers (decode_step ms, CPU)",
        &["Model", "Lever", "before ms", "after ms", "speedup"]);

    for sim in &models {
        let mut session = ModelSession::new(rt.clone(), sim).unwrap();
        let cfg = session.cfg().clone();
        let cache = CacheState::zeros(&cfg, 1);

        // lever 1: literal-path vs resident params
        session.literal_path = true;
        let before = bench.measure(&format!("{sim}.step.literals"), 1.0,
            || { session.decode_step(&cache, &[7]).unwrap(); })
            .summary.mean;
        session.literal_path = false;
        let after = bench.measure(&format!("{sim}.step.resident"), 1.0,
            || { session.decode_step(&cache, &[7]).unwrap(); })
            .summary.mean;
        t.row(vec![sim.to_string(), "resident device params".into(),
                   format!("{:.3}", before * 1e3),
                   format!("{:.3}", after * 1e3),
                   format!("{:.2}x", before / after)]);

        // lever 2: host loop vs compiled scan loop (32 tokens)
        let ss = SingleStream::new(&session);
        let prompt: Vec<i32> = (1..17).collect();
        let host = bench.measure(&format!("{sim}.gen.host"), 32.0,
            || { ss.generate_host(&prompt, 32).unwrap(); })
            .summary.mean;
        let scan = bench.measure(&format!("{sim}.gen.scan"), 32.0,
            || { ss.generate_scan(&prompt, 32).unwrap(); })
            .summary.mean;
        t.row(vec![sim.to_string(), "compiled decode loop".into(),
                   format!("{:.2}", host * 1e3),
                   format!("{:.2}", scan * 1e3),
                   format!("{:.2}x", host / scan)]);

        // lever 3: batched step (4 seqs/launch) vs 4 single steps
        let cache4 = CacheState::zeros(&cfg, 4);
        let single4 = bench.measure(&format!("{sim}.step.4x1"), 4.0, || {
            for _ in 0..4 {
                session.decode_step(&cache, &[7]).unwrap();
            }
        }).summary.mean;
        let batched = bench.measure(&format!("{sim}.step.1x4"), 4.0, || {
            session.decode_step(&cache4, &[7, 8, 9, 10]).unwrap();
        }).summary.mean;
        t.row(vec![sim.to_string(), "batched decode (4 seqs)".into(),
                   format!("{:.2}", single4 * 1e3),
                   format!("{:.2}", batched * 1e3),
                   format!("{:.2}x", single4 / batched)]);
        eprintln!("  [{sim}] done");
    }
    t.print();
    save_results("perf_hotpath", &[&t]);
}
