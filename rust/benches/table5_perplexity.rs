//! Paper Table 5 + Figure 5: perplexity parity between the cached path and
//! the reference path, and batch-size invariance.
//!
//! The paper compares its JAX implementation against the Triton reference
//! (`mamba_ssm`) on WikiText-103 and finds |Δ PPL| ≤ 5e-4. The structural
//! equivalent here (DESIGN.md §4): the *cached decode* scoring path vs the
//! *non-cached strided forward* scoring path on the bundled corpus — two
//! independent routes through the same weights whose agreement is the
//! measured quantity.

use mamba2_serve::bench_support::{open_backend, quick, SIM_MODELS};
use mamba2_serve::eval::corpus::eval_text;
use mamba2_serve::eval::tokenizer::Tokenizer;
use mamba2_serve::eval::{cached_perplexity, strided_perplexity};
use mamba2_serve::runtime::Backend;
use mamba2_serve::util::benchkit::{save_results, Table};

/// Paper Table 5: WikiText-103 PPL (Triton, JAX, |Δ|).
const PAPER_T5: [(&str, f64, f64, f64); 5] = [
    ("130M", 18.7023, 18.7019, 0.0004),
    ("370M", 13.1247, 13.1244, 0.0003),
    ("780M", 10.8892, 10.8886, 0.0005),
    ("1.3B", 9.5708, 9.5704, 0.0004),
    ("2.7B", 8.3252, 8.3250, 0.0002),
];

fn main() {
    let tok = Tokenizer::bytes_only(); // byte ids < 512 = model vocab
    let text = eval_text(0);
    let mut tokens = tok.encode(&text);
    let budget = if quick() { 400 } else { 1200 };
    tokens.truncate(budget);
    let models: Vec<_> = if quick() { SIM_MODELS[..1].to_vec() }
                         else { SIM_MODELS.to_vec() };

    let mut t = Table::new(
        "Perplexity parity: strided reference path vs cached decode path \
         (bundled corpus; paper Table 5 alongside)",
        &["Model", "Ref PPL", "Cached PPL", "|Δ|", "paper Triton",
          "paper JAX", "paper |Δ|"]);
    let mut max_delta = 0.0f64;
    for (i, (sim, paper)) in models.iter().enumerate() {
        let session = open_backend(sim);
        let session = session.as_ref();
        // reference: non-cached strided forward (window 256, stride 128 —
        // the paper's 1024/512 protocol scaled to sim buckets)
        let r = strided_perplexity(session, &tokens, 256, 128).unwrap();
        // implementation under test: prefill + O(1) cached scoring
        let span = 512.min(tokens.len());
        let c = cached_perplexity(session, &tokens[..span], 256).unwrap();
        // parity claim is about identical contexts: rescore the same span
        // in ONE window so both paths condition on the same history
        let r2 = strided_perplexity(session, &tokens[..span], span, span)
            .unwrap();
        let delta = (c.ppl - r2.ppl).abs();
        max_delta = max_delta.max(delta);
        let (_, pt, pj, pd) = PAPER_T5[i.min(4)];
        t.row(vec![sim.to_string(),
                   format!("{:.4}", r2.ppl),
                   format!("{:.4}", c.ppl),
                   format!("{delta:.5}"),
                   format!("{pt:.4}"), format!("{pj:.4}"),
                   format!("{pd:.4}")]);
        eprintln!("  [{sim}] full-corpus ref ppl {:.3} over {} tokens",
                  r.ppl, r.n_tokens);
        let _ = paper;
    }
    t.print();
    println!("max |Δ| = {max_delta:.6} (paper bound: 5e-4; both paths share \
              weights, differ in compute route — same comparison structure)");

    // ------------------- Figure 5: batch-size invariance -----------------
    let mut f5 = Table::new(
        "Fig 5: perplexity vs batch size (sim-130m)",
        &["Batch", "PPL", "|Δ vs b=1|"]);
    let session = open_backend("sim-130m");
    let w = 16; // batched prefill bucket
    // score the same 4 windows at batch 1 and batch 4
    let windows: Vec<Vec<i32>> = (0..4)
        .map(|i| tokens[i * w..(i + 1) * w + 1].to_vec())
        .collect();
    let nll_b1: f64 = windows.iter().map(|win| {
        let pre = session.prefill(&win[..w], 1).unwrap();
        window_nll(&pre.logits, win, w)
    }).sum();
    // batch 4: one batched prefill over the stacked windows
    let stacked: Vec<i32> = windows.iter()
        .flat_map(|win| win[..w].iter().copied()).collect();
    let pre4 = session.prefill(&stacked, 4).unwrap();
    let v = *pre4.logits.dims.last().unwrap() as usize;
    let all = pre4.logits.as_f32();
    let mut nll_b4 = 0.0f64;
    for (b, win) in windows.iter().enumerate() {
        let base = b * w * v;
        for pos in 0..w {
            if pos + 1 > w { break; }
            let row = &all[base + pos * v..base + (pos + 1) * v];
            let target = if pos + 1 < w { win[pos + 1] } else { win[w] };
            nll_b4 -= logp(row, target as usize);
        }
    }
    let n = (w * 4) as f64;
    let p1 = (nll_b1 / n).exp();
    let p4 = (nll_b4 / n).exp();
    f5.row(vec!["1".into(), format!("{p1:.4}"), "0".into()]);
    f5.row(vec!["4".into(), format!("{p4:.4}"),
                format!("{:.6}", (p4 - p1).abs())]);
    f5.print();
    println!("(paper Fig 5: PPL invariant to batch size — |Δ| at f32 \
              rounding scale)");
    save_results("table5_perplexity", &[&t, &f5]);
}

fn logp(row: &[f32], target: usize) -> f64 {
    let m = row.iter().copied().fold(f32::MIN, f32::max) as f64;
    let z: f64 = row.iter().map(|&x| ((x as f64) - m).exp()).sum();
    (row[target] as f64 - m) - z.ln()
}

fn window_nll(logits: &mamba2_serve::tensor::Tensor, win: &[i32], w: usize)
    -> f64 {
    let v = *logits.dims.last().unwrap() as usize;
    let all = logits.as_f32();
    let mut nll = 0.0;
    for pos in 0..w {
        let row = &all[pos * v..(pos + 1) * v];
        nll -= logp(row, win[pos + 1] as usize);
    }
    nll
}
