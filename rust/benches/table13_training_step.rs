//! Paper Table 13: reduced training-step comparison.
//!
//! The paper times forward+backward for its compiler-first chunked path
//! ("JAX") against the kernelised reference ("Triton") and reports a
//! crossover: the chunked path wins at small scale / short sequences and
//! loses as both grow. Here both columns are AOT train-step executables on
//! the same substrate: `train_chunked` (SSD dual form) vs
//! `train_sequential` (naive recurrence standing in for the reference —
//! DESIGN.md §4).

use mamba2_serve::bench_support::{open_runtime, quick};
use mamba2_serve::runtime::ModelSession;
use mamba2_serve::tensor::Tensor;
use mamba2_serve::util::benchkit::{save_results, Bench, Table};

/// Paper Table 13 (ms): (model, seq, jax_ms, triton_ms, delta%).
const PAPER_T13: [(&str, usize, f64, f64, f64); 9] = [
    ("130M", 512, 25.9, 73.7, -64.8),
    ("130M", 1024, 45.2, 72.4, -37.5),
    ("130M", 2048, 86.7, 68.0, 27.6),
    ("370M", 512, 62.8, 147.0, -57.3),
    ("370M", 1024, 115.8, 128.6, -9.9),
    ("370M", 2048, 229.6, 151.4, 51.7),
    ("780M", 512, 104.5, 148.2, -29.5),
    ("780M", 1024, 316.3, 136.3, 132.1),
    ("780M", 2048, 572.9, 148.0, 287.1),
];

fn main() {
    let rt = open_runtime();
    let models = if quick() { vec!["sim-130m"] }
                 else { vec!["sim-130m", "sim-370m", "sim-780m"] };
    let seqs: Vec<usize> = if quick() { vec![32] } else { vec![32, 64, 128] };

    let mut bench = Bench::new().with_protocol(2, 5).quiet();
    let mut t = Table::new(
        "Training step fwd+bwd+adam (ms, CPU, batch 1): chunked SSD vs \
         sequential reference — paper Table 13 alongside (512/1024/2048)",
        &["Model", "Seq", "chunked ms", "sequential ms", "Δ%",
          "paper JAX ms", "paper Triton ms", "paper Δ%"]);

    let mut pi = 0;
    for sim in &models {
        let session = ModelSession::new(rt.clone(), sim).unwrap();
        let n_params = session.params_host.len();
        for &s in &seqs {
            let mut times = Vec::new();
            for mode in ["chunked", "sequential"] {
                let name = format!("{sim}.train_{mode}.t{s}");
                // build the full arg list: params, m, v, step, tokens
                let zeros: Vec<Tensor> = session.params_host.iter()
                    .map(|p| Tensor::zeros_f32(&p.name, &p.dims))
                    .collect();
                let tokens: Vec<i32> = (0..(s + 1) as i32)
                    .map(|i| (i * 11) % 512).collect();
                let tok = Tensor::i32("tokens", &[1, s as i64 + 1], &tokens);
                let step = Tensor::f32("step", &[], &[1.0]);
                let mut extras = session.params_host.clone();
                extras.extend(zeros.iter().cloned());
                extras.extend(zeros.iter().cloned());
                extras.push(step);
                extras.push(tok);
                // train executables take params as plain args; use the
                // literal path (params are also being *updated*, so there
                // is no resident set to reuse)
                let m = bench.measure(&name, 1.0, || {
                    let outs = rt.exec(&name, None, extras.clone(), true)
                        .unwrap();
                    assert_eq!(outs.len(), 3 * n_params + 1);
                });
                times.push(m.summary.mean * 1e3);
            }
            let delta = (times[0] - times[1]) / times[1] * 100.0;
            let (pm, ps, pj, pt, pd) = PAPER_T13[pi.min(8)];
            t.row(vec![sim.to_string(), s.to_string(),
                       format!("{:.1}", times[0]),
                       format!("{:.1}", times[1]),
                       format!("{delta:+.1}"),
                       format!("{pj:.1} ({pm}@{ps})"),
                       format!("{pt:.1}"), format!("{pd:+.1}")]);
            pi += 1;
            eprintln!("  [{sim} t={s}] chunked {:.1}ms sequential {:.1}ms",
                      times[0], times[1]);
        }
    }
    t.print();
    println!("claim under test: the chunked/sequential ratio grows with \
              sequence length (crossover direction matches paper Δ% trend)");
    save_results("table13_training_step", &[&t]);
}
