//! Paper Table 8: precision ablation — what bf16 storage may and may
//! not touch (paper §3.3).
//!
//! The paper's rule: *weights* travel in bf16 for bandwidth,
//! *decays/accumulation* stay f32 for correctness. This bench drives
//! the rule through the repo's REAL precision pass (DESIGN.md §8 —
//! `--weights bf16` on the reference backend, not an artifact-level
//! ablation): decays and accumulation remain f32 by construction, the
//! streamed weight matrices are bf16, and the measured logit shift is
//! the storage-rounding envelope the tolerance suite
//! (`tests/precision_parity.rs`) bounds. Runs hermetically — no XLA,
//! no artifacts.

use mamba2_serve::runtime::{argmax_last, Backend, PlanMode,
                            ReferenceBackend, WeightsDtype};
use mamba2_serve::util::benchkit::{save_results, Bench, Table};

const MODEL: &str = "sim-130m";

fn main() {
    let f32b = ReferenceBackend::seeded(MODEL, 0).unwrap()
        .with_plan_mode(PlanMode::On)
        .with_weights_dtype(WeightsDtype::F32);
    let bf16b = ReferenceBackend::seeded(MODEL, 0).unwrap()
        .with_plan_mode(PlanMode::On)
        .with_weights_dtype(WeightsDtype::Bf16);
    f32b.warm_up(1);
    bf16b.warm_up(1);
    let tokens: Vec<i32> = (0..64).map(|i| (i * 13) % 512).collect();

    // teacher-forced 64-step decode from the shared (bitwise f32)
    // prefill state: the max logit shift the bf16 weight stream causes
    let (mut cf, last) = f32b.prefill_any(&tokens[..16]).unwrap();
    let mut cb = cf.clone();
    let mut tok = argmax_last(&last)[0];
    let mut err = 0.0f32;
    for _ in 0..48 {
        let sf = f32b.decode_step(&cf, &[tok]).unwrap();
        let sb = bf16b.decode_step(&cb, &[tok]).unwrap();
        err = err.max(sf.logits.max_abs_diff(&sb.logits));
        tok = argmax_last(&sf.logits)[0];
        cf = sf.cache;
        cb = sb.cache;
    }

    // prefill stays bitwise f32 in both modes (decays/accumulation and
    // the whole prefill path are precision-exempt)
    let pf = f32b.prefill(&tokens, 1).unwrap();
    let pb = bf16b.prefill(&tokens, 1).unwrap();
    let prefill_err = pf.logits.max_abs_diff(&pb.logits);

    // runtime of the two weight streams on the bandwidth-bound step
    let (cache, _) = f32b.prefill_any(&tokens).unwrap();
    let mut bench = Bench::new().quiet();
    let m32 = bench.measure("decode_f32", 1.0, || {
        f32b.decode_step(&cache, &[7]).unwrap();
    }).summary.mean;
    let mbf = bench.measure("decode_bf16", 1.0, || {
        bf16b.decode_step(&cache, &[7]).unwrap();
    }).summary.mean;

    let mut t = Table::new(
        &format!("Weight/decay precision ablation ({MODEL}, real bf16 \
                  weight path) vs paper Table 8"),
        &["Stream", "Max abs logit shift", "ms/step",
          "paper decay-bf16 error"]);
    t.row(vec!["f32 weights (baseline)".into(), "0.0".into(),
               format!("{:.3}", m32 * 1e3), "0.0".into()]);
    t.row(vec!["bf16 weights, f32 decays+accum".into(),
               format!("{err:.4}"),
               format!("{:.3}", mbf * 1e3), "0.013".into()]);
    t.row(vec!["prefill under bf16 mode (f32 by design)".into(),
               format!("{prefill_err:.4}"), "-".into(), "-".into()]);
    t.print();

    assert!(err > 1e-5,
            "bf16 weight stream must shift decode logits (got {err}); \
             precision pass inert?");
    assert!(err < 0.05,
            "bf16 weight shift {err} above the tolerance-suite bound — \
             is something beyond the weights streaming bf16?");
    assert_eq!(prefill_err, 0.0,
               "prefill must stay bitwise f32 under bf16 mode");
    println!("decode runtime delta: {:+.1}% (bf16 vs f32; negative = \
              the halved stream pays)",
             (mbf / m32 - 1.0) * 100.0);
    save_results("table8_decay_precision", &[&t]);
}
