//! Paper Table 8: precision ablation — what bf16 storage may and may
//! not touch (paper §3.3).
//!
//! The paper's rule: *weights* travel in bf16 for bandwidth,
//! *decays/accumulation* stay f32 for correctness. This bench drives
//! the rule through the repo's REAL precision pass (DESIGN.md §8 —
//! `--weights bf16` on the reference backend, not an artifact-level
//! ablation): decays and accumulation remain f32 by construction, the
//! streamed weight matrices are bf16, and the measured logit shift is
//! the storage-rounding envelope the tolerance suite
//! (`tests/precision_parity.rs`) bounds. Runs hermetically — no XLA,
//! no artifacts.
//!
//! PR 10 appends the group-quantisation accuracy sweep (DESIGN.md
//! §13): int8 and q4 streams at group ∈ {32, 64, 128}, each measured
//! against the same f32 baseline by teacher-forced |ΔPPL| and max
//! per-step |Δlogit|. Smaller groups spend more scale bytes per
//! weight but track each group's amplitude tighter — the table shows
//! that trade directly next to the per-weight stream cost.

use mamba2_serve::runtime::plan::ir::WeightRepr;
use mamba2_serve::runtime::{argmax_last, Backend, PlanMode,
                            ReferenceBackend, WeightsDtype};
use mamba2_serve::util::benchkit::{save_results, Bench, Table};

const MODEL: &str = "sim-130m";

fn log_softmax(row: &[f32], idx: usize) -> f64 {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let z: f64 = row.iter().map(|&x| ((x as f64) - m).exp()).sum();
    (row[idx] as f64) - m - z.ln()
}

/// Teacher-forced perplexity over `tokens[16..]` from a 16-token
/// prefill — the Table 8 accuracy axis.
fn teacher_forced_ppl(backend: &ReferenceBackend, tokens: &[i32]) -> f64 {
    let (mut cache, mut logits) =
        backend.prefill_any(&tokens[..16]).unwrap();
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for &t in &tokens[16..] {
        let row = logits.as_f32();
        sum -= log_softmax(&row, t as usize);
        n += 1;
        let s = backend.decode_step(&cache, &[t]).unwrap();
        cache = s.cache;
        logits = s.logits;
    }
    (sum / n as f64).exp()
}

/// Max per-step |Δlogit| along the f32 greedy trajectory, both
/// backends teacher-forced from the shared (bitwise f32) prefill.
fn max_logit_shift(f32b: &ReferenceBackend, qb: &ReferenceBackend,
                   tokens: &[i32]) -> f32 {
    let (mut cf, last) = f32b.prefill_any(&tokens[..16]).unwrap();
    let mut cq = cf.clone();
    let mut tok = argmax_last(&last)[0];
    let mut err = 0.0f32;
    for _ in 0..48 {
        let sf = f32b.decode_step(&cf, &[tok]).unwrap();
        let sq = qb.decode_step(&cq, &[tok]).unwrap();
        err = err.max(sf.logits.max_abs_diff(&sq.logits));
        tok = argmax_last(&sf.logits)[0];
        cf = sf.cache;
        cq = sq.cache;
    }
    err
}

fn main() {
    let f32b = ReferenceBackend::seeded(MODEL, 0).unwrap()
        .with_plan_mode(PlanMode::On)
        .with_weights_dtype(WeightsDtype::F32);
    let bf16b = ReferenceBackend::seeded(MODEL, 0).unwrap()
        .with_plan_mode(PlanMode::On)
        .with_weights_dtype(WeightsDtype::Bf16);
    f32b.warm_up(1);
    bf16b.warm_up(1);
    let tokens: Vec<i32> = (0..64).map(|i| (i * 13) % 512).collect();

    // teacher-forced 64-step decode from the shared (bitwise f32)
    // prefill state: the max logit shift the bf16 weight stream causes
    let (mut cf, last) = f32b.prefill_any(&tokens[..16]).unwrap();
    let mut cb = cf.clone();
    let mut tok = argmax_last(&last)[0];
    let mut err = 0.0f32;
    for _ in 0..48 {
        let sf = f32b.decode_step(&cf, &[tok]).unwrap();
        let sb = bf16b.decode_step(&cb, &[tok]).unwrap();
        err = err.max(sf.logits.max_abs_diff(&sb.logits));
        tok = argmax_last(&sf.logits)[0];
        cf = sf.cache;
        cb = sb.cache;
    }

    // prefill stays bitwise f32 in both modes (decays/accumulation and
    // the whole prefill path are precision-exempt)
    let pf = f32b.prefill(&tokens, 1).unwrap();
    let pb = bf16b.prefill(&tokens, 1).unwrap();
    let prefill_err = pf.logits.max_abs_diff(&pb.logits);

    // runtime of the two weight streams on the bandwidth-bound step
    let (cache, _) = f32b.prefill_any(&tokens).unwrap();
    let mut bench = Bench::new().quiet();
    let m32 = bench.measure("decode_f32", 1.0, || {
        f32b.decode_step(&cache, &[7]).unwrap();
    }).summary.mean;
    let mbf = bench.measure("decode_bf16", 1.0, || {
        bf16b.decode_step(&cache, &[7]).unwrap();
    }).summary.mean;

    let mut t = Table::new(
        &format!("Weight/decay precision ablation ({MODEL}, real bf16 \
                  weight path) vs paper Table 8"),
        &["Stream", "Max abs logit shift", "ms/step",
          "paper decay-bf16 error"]);
    t.row(vec!["f32 weights (baseline)".into(), "0.0".into(),
               format!("{:.3}", m32 * 1e3), "0.0".into()]);
    t.row(vec!["bf16 weights, f32 decays+accum".into(),
               format!("{err:.4}"),
               format!("{:.3}", mbf * 1e3), "0.013".into()]);
    t.row(vec!["prefill under bf16 mode (f32 by design)".into(),
               format!("{prefill_err:.4}"), "-".into(), "-".into()]);
    t.print();

    assert!(err > 1e-5,
            "bf16 weight stream must shift decode logits (got {err}); \
             precision pass inert?");
    assert!(err < 0.05,
            "bf16 weight shift {err} above the tolerance-suite bound — \
             is something beyond the weights streaming bf16?");
    assert_eq!(prefill_err, 0.0,
               "prefill must stay bitwise f32 under bf16 mode");
    println!("decode runtime delta: {:+.1}% (bf16 vs f32; negative = \
              the halved stream pays)",
             (mbf / m32 - 1.0) * 100.0);

    // group-quantisation accuracy sweep (DESIGN.md §13): the same
    // teacher-forced protocol over int8/q4 at group ∈ {32, 64, 128},
    // every cell measured against the one f32 baseline
    let ppl_f32 = teacher_forced_ppl(&f32b, &tokens);
    let mut qt = Table::new(
        &format!("Group-quantised weight streams ({MODEL}): accuracy \
                  vs group size, teacher-forced vs f32"),
        &["Stream", "group", "bytes/weight", "max |Δlogit|", "|ΔPPL|"]);
    qt.row(vec!["f32 (baseline)".into(), "-".into(), "4.000".into(),
                "0.0".into(), "0.0".into()]);
    for dt in [WeightsDtype::Int8, WeightsDtype::Q4] {
        for group in [32usize, 64, 128] {
            let qb = ReferenceBackend::seeded(MODEL, 0).unwrap()
                .with_plan_mode(PlanMode::On)
                .with_weights_dtype(dt)
                .with_quant_group(group);
            qb.warm_up(1);
            let shift = max_logit_shift(&f32b, &qb, &tokens);
            let dppl = (teacher_forced_ppl(&qb, &tokens) - ppl_f32)
                .abs();
            let repr = match dt {
                WeightsDtype::Int8 => WeightRepr::Int8Group { group },
                _ => WeightRepr::Q4Group { group },
            };
            qt.row(vec![repr.label(), format!("{group}"),
                        format!("{:.3}", repr.bytes_per_weight()),
                        format!("{shift:.4}"), format!("{dppl:.3}")]);
            // each quantised stream must move logits, and tighter
            // groups must never be *pathologically* worse than the
            // storage format allows — the table is diagnostic, the
            // hard per-dtype bounds live in tests/precision_parity.rs
            assert!(shift > 1e-6,
                    "{}: quantised stream inert", repr.label());
            assert!(shift.is_finite() && dppl.is_finite(),
                    "{}: non-finite drift", repr.label());
        }
    }
    qt.print();
    save_results("table8_decay_precision", &[&t, &qt]);
}
