//! Paper Table 8: decay-precision ablation — bf16 exponentiation of the
//! decay parameters shifts the logits measurably; f32 is required.

use mamba2_serve::bench_support::open_runtime;
use mamba2_serve::runtime::ModelSession;
use mamba2_serve::tensor::Tensor;
use mamba2_serve::util::benchkit::{save_results, Bench, Table};

fn main() {
    let rt = open_runtime();
    let session = ModelSession::new(rt.clone(), "sim-130m").unwrap();
    let tokens: Vec<i32> = (0..64).map(|i| (i * 13) % 512).collect();
    let tok = Tensor::i32("tokens", &[1, 64], &tokens);

    let f32_out = session
        .call_named("ablation.decay_float32.forward.t64", vec![tok.clone()])
        .unwrap();
    let bf16_out = session
        .call_named("ablation.decay_bfloat16.forward.t64", vec![tok.clone()])
        .unwrap();
    let err = f32_out[0].max_abs_diff(&bf16_out[0]);

    // runtime cost of the upcast (paper: "no measurable runtime")
    let mut bench = Bench::new().quiet();
    let m32 = bench.measure("decay_f32", 64.0, || {
        session.call_named("ablation.decay_float32.forward.t64",
                           vec![tok.clone()]).unwrap();
    }).summary.mean;
    let mbf = bench.measure("decay_bf16", 64.0, || {
        session.call_named("ablation.decay_bfloat16.forward.t64",
                           vec![tok.clone()]).unwrap();
    }).summary.mean;

    let mut t = Table::new(
        "Decay precision ablation (sim-130m, prompt 64) vs paper Table 8",
        &["Decay dtype", "Max abs logit error", "ms/call", "paper error"]);
    t.row(vec!["float32 (baseline)".into(), "0.0".into(),
               format!("{:.2}", m32 * 1e3), "0.0".into()]);
    t.row(vec!["bfloat16".into(), format!("{err:.4}"),
               format!("{:.2}", mbf * 1e3), "0.013".into()]);
    t.print();

    assert!(err > 1e-5,
            "bf16 decay must shift logits (got {err}); ablation inert?");
    println!("runtime delta: {:+.1}% (paper: no measurable cost)",
             (mbf / m32 - 1.0) * 100.0);
    save_results("table8_decay_precision", &[&t]);
}
