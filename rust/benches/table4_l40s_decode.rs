//! Paper Table 4: single-stream decode on NVIDIA L40S — same source,
//! second hardware target.
//!
//! The portability claim: identical artifacts, different roofline. We
//! project the paper-scale configs under the L40S roofline and verify the
//! paper's shape claims (seq-len independence; host-loop penalty at small
//! scale; absolute numbers below the TPU's).

use mamba2_serve::bench_support::paper_config;
use mamba2_serve::perf::sim::{project_decode, Strategy};
use mamba2_serve::perf::{L40S, TPU_V6E};
use mamba2_serve::util::benchkit::{save_results, Table};

/// Paper Table 4 (tokens/s on L40S) at g = 128 / 1024 / 4096.
const PAPER_T4: [(&str, [f64; 3], [f64; 3], [f64; 3]); 5] = [
    ("130M", [240.2, 267.1, 314.2], [178.4, 141.9, 188.5],
     [203.3, 115.8, 20.3]),
    ("370M", [154.3, 165.1, 148.0], [104.1, 98.8, 112.3],
     [125.4, 36.9, 7.2]),
    ("780M", [110.2, 106.4, 108.0], [107.2, 118.5, 99.6],
     [97.3, 20.4, 3.9]),
    ("1.3B", [67.2, 71.3, 71.0], [71.1, 72.4, 72.5], [65.2, 12.7, 2.7]),
    ("2.7B", [35.4, 36.3, 36.1], [37.2, 37.1, 37.1], [34.8, 6.7, 1.5]),
];

fn main() {
    let gl = [128usize, 1024, 4096];
    let mut t = Table::new(
        "Projected NVIDIA L40S decode throughput vs paper Table 4 \
         (tokens/s, batch 1, bf16)",
        &["Model", "Method", "proj 128", "paper 128", "proj 1024",
          "paper 1024", "proj 4096", "paper 4096"]);
    for (scale, scan_ref, host_ref, nc_ref) in PAPER_T4 {
        let c = paper_config(scale);
        for (method, strat, refs) in [
            ("Cached (scan)", Strategy::CachedScan, scan_ref),
            ("Cached (host)", Strategy::CachedHost, host_ref),
        ] {
            let mut row = vec![scale.to_string(), method.to_string()];
            for (i, &g) in gl.iter().enumerate() {
                let p = project_decode(&c, g, match strat {
                    Strategy::CachedScan => Strategy::CachedScan,
                    Strategy::CachedHost => Strategy::CachedHost,
                    _ => unreachable!(),
                }, &L40S, 2.0);
                row.push(format!("{:.1}", g as f64 / p.seconds));
                row.push(format!("{:.1}", refs[i]));
            }
            t.row(row);
        }
        let mut row = vec![scale.to_string(), "Non-Cached".into()];
        for (i, &g) in gl.iter().enumerate() {
            let p = project_decode(&c, g, Strategy::NonCached { prompt: 16 },
                                   &L40S, 2.0);
            row.push(format!("{:.1}", g as f64 / p.seconds));
            row.push(format!("{:.1}", nc_ref[i]));
        }
        t.row(row);
    }
    t.print();

    // shape checks: L40S < v6e absolute; scan flat; crossover of host gap
    let mut shape = Table::new("Shape checks", &["Claim", "Value", "Holds"]);
    for (scale, ..) in PAPER_T4 {
        let c = paper_config(scale);
        let l = project_decode(&c, 1024, Strategy::CachedScan, &L40S, 2.0);
        let v = project_decode(&c, 1024, Strategy::CachedScan, &TPU_V6E, 2.0);
        shape.row(vec![
            format!("{scale}: L40S slower than v6e"),
            format!("{:.0} vs {:.0} tok/s",
                    1024.0 / l.seconds, 1024.0 / v.seconds),
            (l.seconds > v.seconds).to_string(),
        ]);
        let a = project_decode(&c, 128, Strategy::CachedScan, &L40S, 2.0);
        let b = project_decode(&c, 4096, Strategy::CachedScan, &L40S, 2.0);
        let r = (128.0 / a.seconds) / (4096.0 / b.seconds);
        shape.row(vec![
            format!("{scale}: seq-len independent on L40S"),
            format!("tps ratio {r:.3}"),
            ((r - 1.0).abs() < 0.05).to_string(),
        ]);
    }
    shape.print();
    save_results("table4_l40s_decode", &[&t, &shape]);
    println!("(projection only: no L40S in this environment — \
              DESIGN.md §4 substitution)");
}
