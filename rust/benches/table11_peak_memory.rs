//! Paper Table 11 + Figure 3: peak memory during autoregressive generation.
//!
//! Cached decoding holds peak memory constant; the non-cached path grows
//! with sequence length. Peak bytes here come from the XLA memory analysis
//! recorded per executable at AOT time (args + temps + outputs) plus the
//! resident parameters — the same accounting the paper's device counter
//! reports.

use mamba2_serve::bench_support::{open_runtime, quick, SIM_MODELS};
use mamba2_serve::util::benchkit::{save_results, Table};

fn main() {
    let rt = open_runtime();
    let models: Vec<_> = if quick() { SIM_MODELS[..2].to_vec() }
                         else { SIM_MODELS.to_vec() };
    let lens = [16usize, 32, 64, 128, 256];

    let mut t = Table::new(
        "Peak memory (MB) during generation — XLA memory analysis \
         (cached = decode_step, constant; non-cached = forward_full(t))",
        &["Model", "Method", "t=16", "t=32", "t=64", "t=128", "t=256"]);
    let mut all_hold = true;
    for (sim, _) in &models {
        let cfg = rt.manifest.config(sim).unwrap();
        let params_mb = cfg.param_bytes() as f64 / 1e6;
        let step = rt.manifest.find(&format!("{sim}.decode_step.b1"))
            .unwrap();
        let cached_mb = params_mb
            + step.memory.peak_bytes() as f64 / 1e6;
        let mut row = vec![sim.to_string(), "Cached (O(1))".into()];
        for _ in &lens {
            row.push(format!("{cached_mb:.1}"));
        }
        t.row(row);
        let mut row = vec![sim.to_string(), "Non-Cached".into()];
        let mut prev = 0.0;
        for &l in &lens {
            let f = rt.manifest.find(&format!("{sim}.forward_full.t{l}"))
                .unwrap();
            let mb = params_mb + f.memory.peak_bytes() as f64 / 1e6;
            if mb + 1e-9 < prev {
                all_hold = false;
            }
            prev = mb;
            row.push(format!("{mb:.1}"));
        }
        t.row(row);
    }
    t.print();

    let mut shape = Table::new("Shape checks", &["Claim", "Holds"]);
    shape.row(vec![
        "non-cached peak memory is monotone in sequence length".into(),
        all_hold.to_string(),
    ]);
    for (sim, _) in &models {
        let cfg = rt.manifest.config(sim).unwrap();
        let cache_kb = cfg.cache_bytes_per_seq() as f64 / 1e3;
        shape.row(vec![
            format!("{sim}: O(1) cache footprint {cache_kb:.1} KB \
                     (independent of t)"),
            "true".into(),
        ]);
    }
    shape.print();
    println!("paper Table 11: cached 545.6 MB flat vs non-cached \
              565→1169 MB at 130M; same constant-vs-growing shape above");
    save_results("table11_peak_memory", &[&t, &shape]);
}
