//! Paper Table 3 + Figure 4(b): decode hardware-bandwidth utilisation.
//!
//! HBU = (B / t_wall) / peak BW (paper Eq. 5); B is the unfused byte
//! count from the backend's cost model (XLA cost analysis on the xla
//! backend, the analytic model on the reference backend), so HBU is an
//! upper bound — the same caveat the paper states in §4.1. The paper's
//! claim under test: HBU is
//! constant across sequence lengths (<1.7pp spread) because each step
//! touches the same fixed-size state.

use mamba2_serve::bench_support::{open_backend, paper_config, quick,
                                  SIM_MODELS};
use mamba2_serve::perf::sim::{decode_step_bytes, decode_step_flops};
use mamba2_serve::perf::{hbu, CPU_HOST, TPU_V6E};
use mamba2_serve::runtime::Backend;
use mamba2_serve::util::benchkit::{save_results, Bench, Table};

/// Paper Table 3: decode HBU % by sequence length (128..4096).
const PAPER_T3: [(&str, f64, f64); 5] = [
    // (scale, HBU at 128, HBU at 4096)
    ("130M", 51.62, 53.32),
    ("370M", 57.88, 59.32),
    ("780M", 62.07, 62.99),
    ("1.3B", 61.22, 61.87),
    ("2.7B", 63.43, 64.08),
];

fn main() {
    let models: Vec<_> = if quick() { SIM_MODELS[..2].to_vec() }
                         else { SIM_MODELS.to_vec() };
    // "sequence length" for cached decode = how much prefix was consumed
    // before measuring; O(1) says it cannot matter
    let prefixes: Vec<usize> = if quick() { vec![16] } else { vec![16, 256] };

    let mut bench = Bench::new().quiet();
    let mut measured = Table::new(
        "Measured decode-step HBU % (CPU; B from the backend's cost model)",
        &["Model", "prefix=16", "prefix=256", "spread pp", "step ms"]);

    for (sim, _) in &models {
        let session = open_backend(sim);
        let cost = session.cost("decode_step", None, 1);
        let mut row = vec![sim.to_string()];
        let mut hbus = Vec::new();
        let mut step_ms = 0.0;
        for &pre in &prefixes {
            let tokens: Vec<i32> = (0..pre as i32).map(|i| i % 512).collect();
            let (cache, _) = session.prefill_any(&tokens).unwrap();
            let m = bench.measure(
                &format!("{sim}.step.pre{pre}"), 1.0,
                || { session.decode_step(&cache, &[7]).unwrap(); });
            let h = hbu(&cost, m.summary.mean, CPU_HOST.peak_gbps);
            hbus.push(h);
            row.push(format!("{:.2}", h * 100.0));
            step_ms = m.summary.mean * 1e3;
        }
        while row.len() < 3 { row.push("-".into()); }
        let spread = if hbus.len() > 1 {
            (hbus[1] - hbus[0]).abs() * 100.0
        } else { 0.0 };
        row.push(format!("{spread:.2}"));
        row.push(format!("{step_ms:.2}"));
        measured.row(row);
        eprintln!("  [{sim}] done");
    }
    measured.print();

    // -------- batched decode: per-launch amortisation (extension) -----
    // weights are read once per launch, state per slot, and the
    // batch-fused step spreads the contractions across the pool — so
    // per-token bandwidth economics improve with occupancy
    let mut batched = Table::new(
        "Batch-fused decode-step HBU % / tokens-per-s by batch (CPU)",
        &["Model", "B", "HBU %", "tok/s", "tok/s vs B=1"]);
    for (sim, _) in &models[..1] {
        let session = open_backend(sim);
        let mut base_tps = 0.0;
        for &bsz in &[1usize, 4, 16] {
            let (c1, _) = session
                .prefill_any(&(0..16).collect::<Vec<i32>>()).unwrap();
            let mut cache =
                mamba2_serve::runtime::CacheState::zeros(session.cfg(),
                                                         bsz);
            for s in 0..bsz {
                cache.copy_slot_from(s, &c1, 0);
            }
            let tokens: Vec<i32> = (0..bsz as i32).collect();
            let m = bench.measure(
                &format!("{sim}.step.b{bsz}"), bsz as f64,
                || { session.decode_step(&cache, &tokens).unwrap(); });
            let cost = session.cost("decode_step", None, bsz);
            let tps = bsz as f64 / m.summary.mean;
            if bsz == 1 {
                base_tps = tps;
            }
            batched.row(vec![
                sim.to_string(),
                bsz.to_string(),
                format!("{:.2}",
                        hbu(&cost, m.summary.mean, CPU_HOST.peak_gbps)
                        * 100.0),
                format!("{tps:.1}"),
                format!("{:.2}x", tps / base_tps),
            ]);
        }
    }
    batched.print();

    // -------- projection at paper scale vs paper Table 3 -------------
    let mut proj = Table::new(
        "Projected TPU v6e decode HBU % vs paper Table 3 (batch 1, bf16)",
        &["Model", "projected", "paper @128", "paper @4096"]);
    for (scale, p128, p4096) in PAPER_T3 {
        let c = paper_config(scale);
        let f = decode_step_flops(&c);
        let b = decode_step_bytes(&c, 2.0);
        let secs = TPU_V6E.time_for(f, b);
        let h = (b / secs) / (TPU_V6E.peak_gbps * 1e9);
        proj.row(vec![scale.to_string(),
                      format!("{:.2}", h * 100.0),
                      format!("{p128:.2}"), format!("{p4096:.2}")]);
    }
    proj.print();

    save_results("table3_decode_hbu", &[&measured, &batched, &proj]);
    println!("(HBU constant across prefix lengths == the O(1)-cache claim; \
              spread column is the paper's <1.7pp check)");
}
