//! Extension bench (paper §6 "Inference batch policies"): continuous
//! batching over the O(1) state-slot pool — the scheduler the paper
//! declares compatible with its cache primitive but does not implement.
//!
//! Measures request throughput and latency percentiles as offered
//! concurrency grows, plus the occupancy the batcher sustains. The claim
//! backing the design: because every sequence's state is one fixed slot,
//! admission is O(1) and batching carries no fragmentation overhead, so
//! throughput scales with slot occupancy until compute saturates.

use std::sync::Arc;
use std::time::Duration;

use mamba2_serve::bench_support::{open_backend, quick};
use mamba2_serve::coordinator::{Engine, EngineConfig, GenerateParams};
use mamba2_serve::eval::{corpus, Tokenizer};
use mamba2_serve::gateway::http::http_roundtrip;
use mamba2_serve::gateway::pool::{self, PoolConfig};
use mamba2_serve::gateway::{Gateway, GatewayConfig};
use mamba2_serve::util::benchkit::{save_results, Table};
use mamba2_serve::util::json::Json;
use mamba2_serve::util::prng::Rng;

fn main() {
    let model = "sim-130m";
    let n_requests = if quick() { 8 } else { 24 };
    let gen_len = 24;

    let mut t = Table::new(
        "Continuous batching on the O(1) slot pool (sim-130m, CPU)",
        &["Offered concurrency", "req/s", "tok/s", "ttft p50 ms",
          "e2e p99 ms", "mean occupancy"]);

    // the reference backend is width-flexible (REFERENCE_BATCH_CAP 16)
    // and the engine packs decode to the occupied slots, so wider
    // concurrency sweeps are now worth measuring
    for &conc in if quick() { &[1usize, 4][..] } else { &[1usize, 2, 4, 8] }
    {
        let session = open_backend(model);
        let eng = Arc::new(Engine::start(session, EngineConfig {
            batch_cap: 8,
            max_admissions_per_iter: 4,
            ..Default::default()
        }).unwrap());
        let mut rng = Rng::new(7);
        let t0 = std::time::Instant::now();
        // closed-loop clients at the given concurrency
        let mut handles = Vec::new();
        let per_client = n_requests / conc;
        for c in 0..conc {
            let eng = Arc::clone(&eng);
            let mut crng = rng.fork();
            handles.push(std::thread::spawn(move || {
                for _ in 0..per_client {
                    let plen = 4 + crng.below(12) as usize;
                    let prompt: Vec<i32> = (0..plen)
                        .map(|_| crng.below(512) as i32).collect();
                    let s = eng.generate(prompt, GenerateParams::new()
                        .max_new_tokens(gen_len));
                    s.collect().unwrap();
                }
                let _ = c;
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let snap = eng.metrics.snapshot();
        t.row(vec![conc.to_string(),
                   format!("{:.2}", snap.completed as f64 / wall),
                   format!("{:.1}", snap.tokens_generated as f64 / wall),
                   format!("{:.1}", snap.ttft_p50 * 1e3),
                   format!("{:.1}", snap.e2e_p99 * 1e3),
                   format!("{:.2}", snap.mean_batch_occupancy)]);
        eprintln!("  conc={conc}: {}", snap.render());
    }
    t.print();
    println!("(batched decode shares one executable launch across active \
              slots: higher occupancy amortises the per-step cost)");

    // ---- HTTP sweep: the same closed-loop load through the gateway ------
    // Replica widths through `gateway::pool` + least-in-flight routing;
    // every request is a real `/v1/completions` over a fresh connection,
    // so the row also pays HTTP parsing, tokenization, and JSON assembly.
    let mut th = Table::new(
        "HTTP gateway over the replica pool (closed-loop \
         /v1/completions, sim-130m, CPU)",
        &["Replicas", "Clients", "req/s", "tok/s", "shed", "wall s"]);
    for &nrep in if quick() { &[1usize, 2][..] } else { &[1usize, 2, 4] } {
        let (router, _gauge) = pool::build(PoolConfig {
            model: model.into(),
            replicas: nrep,
            batch_cap: 8,
            ..Default::default()
        }).unwrap();
        let gw = Gateway::new(
            Arc::clone(&router),
            Arc::new(Tokenizer::train(corpus::BUNDLED, 256)),
            GatewayConfig {
                model: model.into(),
                threads: 2 * nrep + 2,
                keep_alive: Duration::from_millis(500),
                ..Default::default()
            });
        let h = gw.start("127.0.0.1:0").unwrap();
        let addr = h.addr();
        let conc = 2 * nrep;
        let per_client = (n_requests / conc).max(1);
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for c in 0..conc {
            handles.push(std::thread::spawn(move || {
                let mut toks = 0u64;
                for r in 0..per_client {
                    let body = format!(
                        "{{\"model\":\"{model}\",\"prompt\":\"client \
                         {c} request {r}\",\"max_tokens\":{gen_len}}}");
                    let (status, _, resp) = http_roundtrip(
                        &addr, "POST", "/v1/completions",
                        body.as_bytes()).expect("gateway roundtrip");
                    assert_eq!(status, 200, "completion failed");
                    toks += std::str::from_utf8(&resp).ok()
                        .and_then(|s| Json::parse(s).ok())
                        .and_then(|j| j.at(&["usage",
                                             "completion_tokens"])
                                  .and_then(Json::as_u64))
                        .unwrap_or(0);
                }
                toks
            }));
        }
        let mut toks = 0u64;
        for hj in handles {
            toks += hj.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let reqs = (conc * per_client) as f64;
        th.row(vec![nrep.to_string(),
                    conc.to_string(),
                    format!("{:.2}", reqs / wall),
                    format!("{:.1}", toks as f64 / wall),
                    h.shed_total().to_string(),
                    format!("{wall:.2}")]);
        eprintln!("  http replicas={nrep}: {reqs:.0} completions in \
                   {wall:.2} s ({toks} tokens)");
        h.drain().unwrap();
    }
    th.print();
    println!("(replica widths share nothing but the in-flight gauge: \
              routing is least-in-flight, admission is O(1) per request)");
    save_results("serving_throughput", &[&t, &th]);
}
