//! Paper Table 1 + Table 10 + Figure 2: single-stream decode strategies.
//!
//! Measures, on the CPU backend, tokens/s for the three decode strategies —
//! Cached (scan) = compiled on-device loop, Cached (host) = host-driven
//! loop, Non-Cached = full-prefix recompute — across the five sim scales
//! and a sweep of generation lengths; then projects the paper-scale
//! configurations onto the TPU-v6e roofline next to the paper's reported
//! numbers. Shape claims under test: cached throughput is sequence-length
//! independent; non-cached collapses; the host-loop penalty appears at
//! small scale and dissolves at large scale.

use mamba2_serve::bench_support::{open_backend, paper_config, quick,
                                  SIM_MODELS};
use mamba2_serve::coordinator::SingleStream;
use mamba2_serve::perf::sim::{project_decode, Strategy};
use mamba2_serve::perf::TPU_V6E;
use mamba2_serve::util::benchkit::{save_results, Bench, Table};

/// Paper Table 1 reference rows (tokens/s on TPU v6e) at g=128/1024/4096.
const PAPER_T1: [(&str, [f64; 3], [f64; 3], [f64; 3]); 5] = [
    ("130M", [1588., 1635., 1641.], [662., 729., 751.], [903., 278., 56.]),
    ("370M", [626., 641., 641.], [392., 391., 390.], [495., 124., 18.]),
    ("780M", [318., 322., 323.], [325., 326., 327.], [311., 60., 9.]),
    ("1.3B", [188., 190., 190.], [192., 192., 192.], [185., 32., 7.]),
    ("2.7B", [94., 95., 95.], [97., 96., 96.], [95., 17., 3.]),
];

fn main() {
    let prompt: Vec<i32> = (1..17).collect(); // paper: prompt fixed at 16
    let gens: Vec<usize> = if quick() { vec![32] } else { vec![32, 128, 256] };
    let gens_nc: Vec<usize> = if quick() { vec![16] } else { vec![32, 128] };
    let models: Vec<_> = if quick() {
        SIM_MODELS[..2].to_vec()
    } else {
        SIM_MODELS.to_vec()
    };

    let mut bench = Bench::new().quiet();
    let mut measured = Table::new(
        "Measured decode throughput (tokens/s, CPU backend, batch 1)",
        &["Model", "Method", "g=32", "g=128", "g=256"]);

    for (sim, _paper) in &models {
        let session = open_backend(sim);
        let ss = SingleStream::new(session.as_ref());

        let mut row_scan = vec![sim.to_string(), "Cached (scan)".into()];
        let mut row_host = vec![sim.to_string(), "Cached (host)".into()];
        let mut row_nc = vec![sim.to_string(), "Non-Cached".into()];
        for &g in &gens {
            let m = bench.measure(&format!("{sim}.scan.g{g}"), g as f64,
                                  || { ss.generate_scan(&prompt, g).unwrap(); });
            row_scan.push(format!("{:.1}", m.throughput()));
            let m = bench.measure(&format!("{sim}.host.g{g}"), g as f64,
                                  || { ss.generate_host(&prompt, g).unwrap(); });
            row_host.push(format!("{:.1}", m.throughput()));
            if gens_nc.contains(&g) {
                let m = bench.measure(
                    &format!("{sim}.noncached.g{g}"), g as f64,
                    || { ss.generate_noncached(&prompt, g).unwrap(); });
                row_nc.push(format!("{:.1}", m.throughput()));
            } else {
                row_nc.push("-".into());
            }
        }
        while row_scan.len() < 5 { row_scan.push("-".into()); }
        while row_host.len() < 5 { row_host.push("-".into()); }
        while row_nc.len() < 5 { row_nc.push("-".into()); }
        measured.row(row_scan);
        measured.row(row_host);
        measured.row(row_nc);
        eprintln!("  [{sim}] done");
    }
    measured.print();

    // ---------------- projection to TPU v6e at paper scale (Table 1) -----
    let mut proj = Table::new(
        "Projected TPU v6e decode throughput vs paper Table 1 \
         (tokens/s, batch 1, bf16)",
        &["Model", "Method", "proj 128", "paper 128", "proj 1024",
          "paper 1024", "proj 4096", "paper 4096"]);
    let gl = [128usize, 1024, 4096];
    for (scale, scan_ref, host_ref, nc_ref) in PAPER_T1 {
        let c = paper_config(scale);
        let mut row = vec![scale.to_string(), "Cached (scan)".into()];
        for (i, &g) in gl.iter().enumerate() {
            let p = project_decode(&c, g, Strategy::CachedScan, &TPU_V6E, 2.0);
            row.push(format!("{:.0}", g as f64 / p.seconds));
            row.push(format!("{:.0}", scan_ref[i]));
        }
        proj.row(row);
        let mut row = vec![scale.to_string(), "Cached (host)".into()];
        for (i, &g) in gl.iter().enumerate() {
            let p = project_decode(&c, g, Strategy::CachedHost, &TPU_V6E, 2.0);
            row.push(format!("{:.0}", g as f64 / p.seconds));
            row.push(format!("{:.0}", host_ref[i]));
        }
        proj.row(row);
        let mut row = vec![scale.to_string(), "Non-Cached".into()];
        for (i, &g) in gl.iter().enumerate() {
            let p = project_decode(&c, g, Strategy::NonCached { prompt: 16 },
                                   &TPU_V6E, 2.0);
            row.push(format!("{:.0}", g as f64 / p.seconds));
            row.push(format!("{:.0}", nc_ref[i]));
        }
        proj.row(row);
    }
    proj.print();

    // ------------------------------------ shape checks (Figure 2 claims) --
    let mut shape = Table::new(
        "Shape checks (measured, CPU)",
        &["Claim", "Value", "Holds"]);
    // cached seq-len independence: scan tps at g=256 vs g=32 within 20%
    if !quick() {
        for (sim, _) in &models {
            let a = bench.get(&format!("{sim}.scan.g32")).unwrap()
                .throughput();
            let b = bench.get(&format!("{sim}.scan.g256")).unwrap()
                .throughput();
            let ratio = b / a;
            shape.row(vec![
                format!("{sim}: cached tps flat in seq len"),
                format!("tps(256)/tps(32) = {ratio:.3}"),
                (ratio > 0.8 && ratio < 1.3).to_string(),
            ]);
            let n1 = bench.get(&format!("{sim}.noncached.g32")).unwrap()
                .throughput();
            let n2 = bench.get(&format!("{sim}.noncached.g128")).unwrap()
                .throughput();
            shape.row(vec![
                format!("{sim}: non-cached collapses"),
                format!("tps(128)/tps(32) = {:.3}", n2 / n1),
                (n2 < n1).to_string(),
            ]);
        }
    }
    shape.print();

    save_results("table1_decode_strategies", &[&measured, &proj, &shape]);
    println!("(projected columns use the roofline model of DESIGN.md §4; \
              measured columns are real CPU-backend runs)");
}
