//! Paper Table 12: one-time XLA compilation cost by model scale.
//!
//! Two compilers are measured: the build-time python lowering + CPU compile
//! (recorded in the manifest by aot.py) and the rust-side PJRT compile of
//! the HLO text at load time (measured here). The paper's claim: one-time
//! cost growing with scale and decode horizon, amortised across calls.

use mamba2_serve::bench_support::{open_runtime, quick, SIM_MODELS};
use mamba2_serve::util::benchkit::{save_results, Table};

/// Paper Table 12: JIT compile seconds (prefill 1024 / decode 128 / 4096).
const PAPER_T12: [(&str, [f64; 3]); 5] = [
    ("130M", [5.5, 5.6, 2.5]),
    ("370M", [10.2, 13.0, 6.4]),
    ("780M", [13.0, 13.7, 12.6]),
    ("1.3B", [10.2, 14.9, 21.4]),
    ("2.7B", [15.8, 19.5, 43.0]),
];

fn main() {
    let rt = open_runtime();
    let models: Vec<_> = if quick() { SIM_MODELS[..2].to_vec() }
                         else { SIM_MODELS.to_vec() };

    let mut t = Table::new(
        "Compile cost (seconds): rust PJRT compile (measured now) and \
         python lower+compile (manifest)",
        &["Model", "rust prefill.512", "rust decode_loop.128",
          "rust decode_loop.256", "py lower+compile (sum of same)",
          "paper (1024/128/4096)"]);

    let mut grows = true;
    let mut prev_total = 0.0;
    for (i, (sim, _)) in models.iter().enumerate() {
        let mut rust_times = Vec::new();
        let mut py_total = 0.0;
        for name in [format!("{sim}.prefill.t512"),
                     format!("{sim}.decode_loop.g128"),
                     format!("{sim}.decode_loop.g256")] {
            let (spec, secs) = rt.load(&name).unwrap();
            rust_times.push(secs);
            py_total += spec.lower_seconds + spec.cpu_compile_seconds;
        }
        let total: f64 = rust_times.iter().sum();
        if total < prev_total * 0.5 {
            grows = false; // compile time should broadly grow with scale
        }
        prev_total = total;
        let p = PAPER_T12[i.min(4)].1;
        t.row(vec![sim.to_string(),
                   format!("{:.2}", rust_times[0]),
                   format!("{:.2}", rust_times[1]),
                   format!("{:.2}", rust_times[2]),
                   format!("{py_total:.2}"),
                   format!("{:.1}/{:.1}/{:.1}", p[0], p[1], p[2])]);
        eprintln!("  [{sim}] compiled");
    }
    t.print();

    // second-load cost must be ~zero (compile cache, "one-time cost")
    let t0 = std::time::Instant::now();
    let _ = rt.load(&format!("{}.prefill.t512", models[0].0)).unwrap();
    let cached = t0.elapsed().as_secs_f64();
    let mut shape = Table::new("Shape checks", &["Claim", "Value", "Holds"]);
    shape.row(vec![
        "second load hits the compile cache".into(),
        format!("{:.4}s", cached),
        (cached < 0.05).to_string(),
    ]);
    shape.row(vec![
        "compile cost grows with scale".into(),
        String::new(),
        grows.to_string(),
    ]);
    shape.print();
    save_results("table12_compile_time", &[&t, &shape]);
}
