//! Paper Table 7: masking ablation — static `tril` vs row-wise runtime
//! masking inside a `fori_loop`.
//!
//! Both artifact variants were lowered from identical weights; output must
//! be bitwise identical while the dynamic variant pays a large throughput
//! penalty because the loop boundary breaks XLA's fusion chain
//! (paper: −82.8% on TPU v6e at 1.3B / prompt 1024; here: sim-1.3b /
//! prompt 64 on CPU).

use mamba2_serve::bench_support::open_runtime;
use mamba2_serve::runtime::ModelSession;
use mamba2_serve::tensor::Tensor;
use mamba2_serve::util::benchkit::{save_results, Bench, Table};

fn main() {
    let rt = open_runtime();
    let session = ModelSession::new(rt.clone(), "sim-1.3b").unwrap();
    let tokens: Vec<i32> = (0..64).map(|i| (i * 7) % 512).collect();
    let tok = Tensor::i32("tokens", &[1, 64], &tokens);

    let mut bench = Bench::new().quiet();
    let mut outs: Vec<Vec<f32>> = Vec::new();
    let mut rows = Vec::new();
    for variant in ["static", "dynamic"] {
        let name = format!("ablation.mask_{variant}.prefill.t64");
        // correctness first
        let o = session.call_named(&name, vec![tok.clone()]).unwrap();
        outs.push(o[0].as_f32());
        let m = bench.measure(&name, 64.0, || {
            session.call_named(&name, vec![tok.clone()]).unwrap();
        });
        rows.push((variant, m.throughput(), m.summary.mean));
    }
    let bitwise = outs[0] == outs[1];
    let penalty = 1.0 - rows[1].1 / rows[0].1;

    let mut t = Table::new(
        "Masking ablation (sim-1.3b, prompt 64, CPU) vs paper Table 7",
        &["Strategy", "Prefill tok/s", "ms/call", "Output", "paper"]);
    t.row(vec!["Static mask (jnp.tril)".into(),
               format!("{:.1}", rows[0].1),
               format!("{:.2}", rows[0].2 * 1e3),
               "—".into(), "42,631 tok/s".into()]);
    t.row(vec!["Dynamic row-wise (fori_loop)".into(),
               format!("{:.1} ({:+.1}%)", rows[1].1, -penalty * 100.0),
               format!("{:.2}", rows[1].2 * 1e3),
               if bitwise { "bitwise identical".into() }
               else { "DIVERGED".to_string() },
               "7,330 tok/s (−82.8%)".into()]);
    t.print();

    assert!(bitwise, "ablation variants must produce identical logits");
    println!("measured penalty: {:.1}% (paper: 82.8% on TPU v6e — the CPU \
              backend fuses differently but the static mask must win)",
             penalty * 100.0);
    save_results("table7_masking_ablation", &[&t]);
}
