//! Paper Table 6: numerical parity tolerances.
//!
//! The paper checks its JAX implementation element-wise against the
//! PyTorch/CUDA reference: last hidden state to 1e-4, first-256 logits to
//! 2e-4 (float32, TF32 off). Here the independent pair is the rust-executed
//! AOT path vs the python-side goldens (generated under
//! jax_default_matmul_precision="highest"), plus the Pallas-kernel variant
//! vs the jnp path.

use std::path::Path;

use mamba2_serve::bench_support::open_runtime;
use mamba2_serve::runtime::{Backend, ModelSession};
use mamba2_serve::tensor::{find, load_mbt};
use mamba2_serve::util::benchkit::{save_results, Table};

fn main() {
    let rt = open_runtime();
    let session = ModelSession::new(rt.clone(), "tiny").unwrap();
    let g = load_mbt(Path::new(&mamba2_serve::artifacts_dir())
                     .join("goldens/tiny.mbt").as_path()).unwrap();
    let tokens = find(&g, "tokens").unwrap().as_i32();

    let mut t = Table::new(
        "Numerical parity vs python goldens (tiny, 32 tokens) — paper \
         Table 6 tolerances",
        &["Output", "max |Δ|", "tolerance", "within"]);

    // final SSM state ≈ "last hidden state"
    let (cache, last_logits) = session.prefill_any(&tokens).unwrap();
    let dssm = cache.ssm.max_abs_diff(find(&g, "cache_ssm").unwrap());
    t.row(vec!["Final SSM state".into(), format!("{dssm:.2e}"),
               "1e-4".into(), (dssm < 1e-4).to_string()]);

    // last-position logits (the decode-relevant ones)
    let want = find(&g, "prefill_logits").unwrap();
    let v = *want.dims.last().unwrap() as usize;
    let wall = want.as_f32();
    let wrow = &wall[wall.len() - v..];
    let grow = last_logits.as_f32();
    let dlog = wrow.iter().zip(&grow).map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    t.row(vec!["Logits (last position)".into(), format!("{dlog:.2e}"),
               "2e-4".into(), (dlog < 2e-4).to_string()]);

    // full forward logits vs goldens
    let full = session.forward_full(&tokens).unwrap();
    let dfull = full.max_abs_diff(find(&g, "forward_full_logits").unwrap());
    t.row(vec!["Logits (all 32 positions)".into(), format!("{dfull:.2e}"),
               "2e-4".into(), (dfull < 2e-4).to_string()]);

    // Pallas L1 kernel vs jnp path (executable level)
    let tok_t = find(&g, "tokens").unwrap().clone();
    let pall = session
        .call_named("ablation.pallas.prefill.t32", vec![tok_t]).unwrap();
    let dpal = pall[0].max_abs_diff(want);
    t.row(vec!["Pallas-kernel logits vs jnp path".into(),
               format!("{dpal:.2e}"), "2e-4".into(),
               (dpal < 2e-4).to_string()]);

    // generated tokens must be bitwise equal
    let (cache2, ll2) = session.prefill_any(&tokens).unwrap();
    let first = ModelSession::argmax_last(&ll2)[0];
    let (gen, _) = session.decode_loop(&cache2, first, 16).unwrap();
    let bitwise = gen == find(&g, "gen_tokens").unwrap().as_i32();
    t.row(vec!["Greedy tokens (16 steps)".into(),
               if bitwise { "0 (bitwise)".into() } else { "≠".to_string() },
               "exact".into(), bitwise.to_string()]);
    t.print();

    for row in &t.rows {
        assert_eq!(row[3], "true", "parity violated: {row:?}");
    }
    println!("paper Table 6: hidden state 1e-4, logits 2e-4 — all satisfied");
    save_results("table6_parity", &[&t]);
}
